//! Load-generator layer: replay a [`WorkloadMix`] against the serving
//! engine and record what every request experienced.
//!
//! Two modes share the same per-client plans ([`super::arrivals`]):
//!
//! - [`run_live`] drives the **real** [`Engine`] — worker threads,
//!   channels, the deadline batcher — with one OS thread per client.
//!   Wall-clock timing is real, so latencies are host-dependent; reply
//!   *contents* are not, and `verify` checks every completed reply
//!   bit-for-bit against an unbatched reference forward (safe because
//!   `Model::forward_batch` is pinned bit-identical to per-request
//!   forwards).
//! - [`run_virtual`] replays the plan on a virtual clock: a
//!   discrete-event mirror of the batcher policy (full-batch and
//!   deadline flushes, backpressure sheds, per-model grouping) with
//!   service times from the L2 cost model (`costmodel`, ex5-big core).
//!   Fully deterministic — same mix ⇒ identical trace — which is what
//!   CI and the sweep figures run on.
//!
//! Both modes drive a real [`Metrics`] instance, so a report built from
//! the trace can reconcile record counts against engine counters
//! exactly ([`super::report::build_report`]).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::sync::atomic::Ordering::Relaxed;
use std::time::{Duration, Instant};

use super::arrivals::client_plan;
use super::mix::WorkloadMix;
use crate::coordinator::{Engine, Metrics, ModelCounters};
use crate::costmodel::{simulate_model_total, CachePreset, CoreModel};
use crate::figures::e2e::fullpack_methods_for;
use crate::models::{CompiledModel, Model, ModelGraph, ModelRegistry};
use crate::util::error::{anyhow, bail, Result};
use crate::util::rng::SplitMix64;

/// What happened to one planned request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// replied successfully
    Completed,
    /// rejected at submission by queue backpressure
    Shed,
    /// replied with an error
    Error,
}

impl Outcome {
    /// Schema label (`completed`/`shed`/`error`).
    pub fn name(&self) -> &'static str {
        match self {
            Outcome::Completed => "completed",
            Outcome::Shed => "shed",
            Outcome::Error => "error",
        }
    }
}

/// One request's observed fate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestRecord {
    /// issuing client
    pub client: usize,
    /// per-client request index (plan order)
    pub index: usize,
    /// index into `mix.models`
    pub model: usize,
    /// submission time, ns since run start
    pub submit_ns: u64,
    /// end-to-end latency in µs (0 for shed requests)
    pub latency_us: u64,
    /// what happened
    pub outcome: Outcome,
}

/// By-value snapshot of the engine's [`Metrics`] at run end.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EngineSnapshot {
    /// requests accepted at submission (sheds included)
    pub requests: u64,
    /// requests served to completion
    pub completed: u64,
    /// requests that failed
    pub errors: u64,
    /// requests served through a multi-request batched dispatch
    pub batched_requests: u64,
    /// requests served individually
    pub singleton_requests: u64,
    /// multi-request batched dispatches
    pub batched_dispatches: u64,
    /// `(full, deadline, drained)` batch-flush counts
    pub flushes: (u64, u64, u64),
    /// per-model counters, sorted by registered name
    pub per_model: Vec<(String, ModelCounters)>,
}

impl EngineSnapshot {
    /// Capture the current counter values.
    pub fn capture(m: &Metrics) -> EngineSnapshot {
        EngineSnapshot {
            requests: m.requests.load(Relaxed),
            completed: m.completed.load(Relaxed),
            errors: m.errors.load(Relaxed),
            batched_requests: m.batched_requests.load(Relaxed),
            singleton_requests: m.singleton_requests.load(Relaxed),
            batched_dispatches: m.batched_dispatches.load(Relaxed),
            flushes: m.flush_counts(),
            per_model: m.per_model_counters(),
        }
    }
}

/// Everything one run produced: per-request records plus the engine's
/// own counters, for reconciliation in the report layer.
#[derive(Debug, Clone, PartialEq)]
pub struct RunTrace {
    /// `"live"` or `"virtual"`
    pub mode: &'static str,
    /// run duration in ns (real for live, virtual-clock for virtual)
    pub wall_ns: u64,
    /// one record per planned request, sorted by `(client, index)`
    pub records: Vec<RequestRecord>,
    /// engine counters at run end
    pub snapshot: EngineSnapshot,
}

/// Deterministic request frames: the first `fill` fraction of the
/// model's fixed input window carries pseudo-random signal, the rest is
/// zero padding (a shorter utterance in a fixed-shape window — the
/// engine shape-validates, so the window itself never shrinks).
fn gen_frames(len: usize, fill: f64, seed: u64) -> Vec<f32> {
    let signal = ((fill * len as f64).round() as usize).clamp(1, len);
    let mut rng = SplitMix64::new(seed);
    let mut frames = vec![0.0f32; len];
    for f in frames.iter_mut().take(signal) {
        *f = rng.f64_in(-1.0, 1.0) as f32;
    }
    frames
}

/// Frame-seed stream id for `(client, index)` — disjoint from the plan
/// streams (which use bare client ids) via the high bit.
fn frame_stream(client: usize, index: usize) -> u64 {
    0x8000_0000_0000_0000 | ((client as u64) << 32) | index as u64
}

/// Build the mix's models: compiled instances for the engine roster
/// plus the graphs (for the virtual cost model and verify references).
fn build_models(mix: &WorkloadMix) -> Result<Vec<(ModelGraph, CompiledModel)>> {
    let mut out = Vec::with_capacity(mix.models.len());
    for m in &mix.models {
        let graph = ModelRegistry::global().build(
            &m.spec.model,
            m.spec.size,
            m.spec.variant,
            m.spec.seed,
        )?;
        let compiled = CompiledModel::compile(graph.clone())
            .map_err(|e| anyhow!("compiling {:?}: {e}", m.spec.name))?;
        out.push((graph, compiled));
    }
    Ok(out)
}

/// Replay `mix` against a live [`Engine`]: one thread per client, real
/// batcher, real workers.  With `verify`, every completed reply is
/// checked bit-for-bit against an unbatched reference forward of the
/// same frames.  Returns the trace with records sorted by
/// `(client, index)`.
pub fn run_live(mix: &WorkloadMix, verify: bool) -> Result<RunTrace> {
    mix.validate()?;
    let engine = Engine::new(mix.engine);
    // register one compiled instance and keep an independent reference
    // instance for verification
    let refs: Vec<CompiledModel> = {
        let mut refs = Vec::with_capacity(mix.models.len());
        for (i, (graph, compiled)) in build_models(mix)?.into_iter().enumerate() {
            engine.register_model(&mix.models[i].spec.name, compiled);
            refs.push(
                CompiledModel::compile(graph)
                    .map_err(|e| anyhow!("compiling reference: {e}"))?,
            );
        }
        refs
    };
    let t0 = Instant::now();
    let results: Vec<Result<Vec<RequestRecord>>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..mix.clients)
            .map(|client| {
                let engine = &engine;
                let refs = &refs;
                scope.spawn(move || client_loop(mix, client, engine, refs, verify, t0))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("client thread panicked"))))
            .collect()
    });
    let wall_ns = t0.elapsed().as_nanos() as u64;
    let mut records = Vec::with_capacity(mix.total_requests());
    for r in results {
        records.extend(r?);
    }
    records.sort_by_key(|r| (r.client, r.index));
    // all replies are in hand: the snapshot is quiescent
    let snapshot = EngineSnapshot::capture(engine.metrics());
    engine.shutdown();
    Ok(RunTrace { mode: "live", wall_ns, records, snapshot })
}

/// One live client: walk the plan, submit bursts, collect replies.
fn client_loop(
    mix: &WorkloadMix,
    client: usize,
    engine: &Engine,
    refs: &[CompiledModel],
    verify: bool,
    t0: Instant,
) -> Result<Vec<RequestRecord>> {
    let plan = client_plan(mix, client);
    let open_loop = mix.arrival.is_open_loop();
    let mut records = Vec::with_capacity(mix.requests_per_client);
    // open loop: in-flight requests drained after all submissions
    let mut pending: Vec<(usize, usize, u64, Vec<f32>, std::sync::mpsc::Receiver<_>)> =
        Vec::new();
    let mut index = 0usize;
    // open loop tracks absolute arrival deadlines so sleep jitter does
    // not accumulate drift across bursts
    let mut t_next = Duration::ZERO;
    for burst in &plan {
        if open_loop {
            t_next += Duration::from_nanos(burst.gap_ns);
            let target = t0 + t_next;
            let now = Instant::now();
            if target > now {
                std::thread::sleep(target - now);
            }
        } else if burst.gap_ns > 0 {
            std::thread::sleep(Duration::from_nanos(burst.gap_ns));
        }
        let mut inline: Vec<(usize, usize, u64, Vec<f32>, std::sync::mpsc::Receiver<_>)> =
            Vec::new();
        for req in &burst.requests {
            let model = &mix.models[req.model];
            let len = refs[req.model].input_len();
            let frames = gen_frames(len, req.fill, SplitMix64::stream(
                mix.seed,
                frame_stream(client, index),
            ).next_u64());
            let submit_ns = t0.elapsed().as_nanos() as u64;
            match engine.submit(&model.spec.name, frames.clone()) {
                Ok(rx) => {
                    let slot = (index, req.model, submit_ns, frames, rx);
                    if open_loop {
                        pending.push(slot);
                    } else {
                        inline.push(slot);
                    }
                }
                Err(_) => records.push(RequestRecord {
                    client,
                    index,
                    model: req.model,
                    submit_ns,
                    latency_us: 0,
                    outcome: Outcome::Shed,
                }),
            }
            index += 1;
        }
        // closed loop: the burst must complete before the think timer
        for slot in inline {
            records.push(collect_reply(client, slot, refs, verify)?);
        }
    }
    for slot in pending {
        records.push(collect_reply(client, slot, refs, verify)?);
    }
    Ok(records)
}

/// Wait for one reply and turn it into a record (verifying if asked).
fn collect_reply(
    client: usize,
    (index, model, submit_ns, frames, rx): (
        usize,
        usize,
        u64,
        Vec<f32>,
        std::sync::mpsc::Receiver<Result<crate::coordinator::Response>>,
    ),
    refs: &[CompiledModel],
    verify: bool,
) -> Result<RequestRecord> {
    let reply = rx.recv().map_err(|_| anyhow!("engine dropped request"))?;
    Ok(match reply {
        Ok(resp) => {
            if verify {
                let (expect, _) = refs[model].forward_timed(&frames);
                if resp.logits != expect {
                    bail!(
                        "reply mismatch: client {client} request {index}: batched \
                         logits differ from the per-request reference"
                    );
                }
            }
            RequestRecord {
                client,
                index,
                model,
                submit_ns,
                latency_us: (resp.total_ns / 1_000) as u64,
                outcome: Outcome::Completed,
            }
        }
        Err(_) => RequestRecord {
            client,
            index,
            model,
            submit_ns,
            latency_us: 0,
            outcome: Outcome::Error,
        },
    })
}

/// Discrete-event state: what kind of wake-up an event is.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Ev {
    /// client's burst arrives
    Arrival {
        /// issuing client
        client: usize,
        /// burst index in the client's plan
        burst: usize,
    },
    /// a worker finished its flush
    WorkerFree,
    /// the oldest queued request's max-wait deadline passed
    Deadline,
}

/// One queued (virtual) request.
#[derive(Debug, Clone, Copy)]
struct QItem {
    enq_ns: u64,
    client: usize,
    index: usize,
    model: usize,
}

/// Replay `mix` on a virtual clock: a deterministic discrete-event
/// mirror of the engine's batcher policy with cost-model service times
/// (ex5-big core, gem5 cache preset — ns = cycles / freq).  Drives a
/// real [`Metrics`] instance so reports reconcile exactly.  Same mix ⇒
/// byte-identical trace.
pub fn run_virtual(mix: &WorkloadMix) -> Result<RunTrace> {
    mix.validate()?;
    let models = build_models(mix)?;
    let metrics = Metrics::default();
    let core = CoreModel::ex5_big();
    let preset = CachePreset::Gem5Ex5Big;
    // service time of one flushed group of n same-model requests: the
    // batched forward widens every layer to n·time_steps columns, which
    // is exactly a graph with time_steps scaled by n
    let mut svc_memo: HashMap<(usize, usize), u64> = HashMap::new();
    let mut svc_ns = |model: usize, n: usize| -> u64 {
        *svc_memo.entry((model, n)).or_insert_with(|| {
            let mut g = models[model].0.clone();
            g.time_steps *= n;
            let (cell_m, fc_m) = fullpack_methods_for(&g);
            let cycles = simulate_model_total(&g, cell_m, fc_m, preset, &core, 2);
            (cycles / core.freq_ghz) as u64
        })
    };

    let max_batch = mix.engine.batcher.max_batch;
    let max_queue = mix.engine.batcher.max_queue;
    let max_wait_ns = mix.engine.batcher.max_wait.as_nanos() as u64;
    let workers = mix.engine.workers.max(1);
    let mut free_at = vec![0u64; workers];

    let plans: Vec<_> = (0..mix.clients).map(|c| client_plan(mix, c)).collect();
    // per-client replay cursors (closed loop schedules burst n+1 only
    // after burst n fully completes)
    let mut next_index = vec![0usize; mix.clients];
    let mut outstanding = vec![0usize; mix.clients];
    let mut done_bursts = vec![0usize; mix.clients];

    let mut heap: BinaryHeap<Reverse<(u64, u64, Ev)>> = BinaryHeap::new();
    let mut seq = 0u64;

    let open_loop = mix.arrival.is_open_loop();
    if open_loop {
        // every arrival time is known up front
        for (client, plan) in plans.iter().enumerate() {
            let mut t = 0u64;
            for (b, burst) in plan.iter().enumerate() {
                t += burst.gap_ns;
                push_ev(&mut heap, &mut seq, t, Ev::Arrival { client, burst: b });
            }
        }
    } else {
        for (client, plan) in plans.iter().enumerate() {
            push_ev(&mut heap, &mut seq, plan[0].gap_ns, Ev::Arrival { client, burst: 0 });
        }
    }

    let mut queue: VecDeque<QItem> = VecDeque::new();
    let mut records = Vec::with_capacity(mix.total_requests());
    let mut wall_ns = 0u64;

    while let Some(Reverse((t, _, ev))) = heap.pop() {
        wall_ns = wall_ns.max(t);
        if let Ev::Arrival { client, burst } = ev {
            metrics.mark_started();
            for req in &plans[client][burst].requests {
                let index = next_index[client];
                next_index[client] += 1;
                // mirror Engine::submit exactly: the request counter
                // includes sheds, which never reach a worker
                metrics.requests.fetch_add(1, Relaxed);
                if queue.len() >= max_queue {
                    records.push(RequestRecord {
                        client,
                        index,
                        model: req.model,
                        submit_ns: t,
                        latency_us: 0,
                        outcome: Outcome::Shed,
                    });
                } else {
                    queue.push_back(QItem { enq_ns: t, client, index, model: req.model });
                    outstanding[client] += 1;
                }
            }
            // a fully-shed closed-loop burst completes immediately
            if !open_loop && outstanding[client] == 0 {
                schedule_next_burst(&plans, client, burst, t, &mut done_bursts, &mut heap, &mut seq);
            }
        }
        // dispatch: a free worker flushes when the batch is full or the
        // oldest entry is past its deadline (no force-drain — matching
        // a live engine in steady state, where Drained stays 0)
        loop {
            if queue.is_empty() {
                break;
            }
            let Some(w) = (0..workers).filter(|&w| free_at[w] <= t).min_by_key(|&w| free_at[w])
            else {
                break; // a WorkerFree event is pending
            };
            let full = queue.len() >= max_batch;
            let due = t >= queue.front().unwrap().enq_ns + max_wait_ns;
            if !(full || due) {
                push_ev(
                    &mut heap,
                    &mut seq,
                    queue.front().unwrap().enq_ns + max_wait_ns,
                    Ev::Deadline,
                );
                break;
            }
            metrics.record_flush(if full {
                crate::coordinator::FlushReason::Full
            } else {
                crate::coordinator::FlushReason::Deadline
            });
            let n = queue.len().min(max_batch);
            let batch: Vec<QItem> = queue.drain(..n).collect();
            // group by model preserving arrival order (dispatch_flush)
            let mut groups: Vec<(usize, Vec<QItem>)> = Vec::new();
            for item in batch {
                match groups.iter_mut().find(|(m, _)| *m == item.model) {
                    Some((_, v)) => v.push(item),
                    None => groups.push((item.model, vec![item])),
                }
            }
            let mut t_cursor = t;
            for (model, items) in groups {
                let name = &mix.models[model].spec.name;
                let svc = svc_ns(model, items.len());
                if items.len() >= 2 {
                    metrics.record_batched_dispatch(name, items.len() as u64);
                } else {
                    metrics.record_singleton(name, 1);
                }
                for item in &items {
                    // queue wait measured at this group's dispatch,
                    // plus the whole group's forward — process_group
                    let latency_ns = (t_cursor - item.enq_ns) + svc;
                    let latency_us = latency_ns / 1_000;
                    metrics.observe_latency_for(name, latency_us);
                    records.push(RequestRecord {
                        client: item.client,
                        index: item.index,
                        model: item.model,
                        submit_ns: item.enq_ns,
                        latency_us,
                        outcome: Outcome::Completed,
                    });
                }
                t_cursor += svc;
                // closed loop: a finished burst unblocks its client
                for item in &items {
                    outstanding[item.client] -= 1;
                    if !open_loop && outstanding[item.client] == 0 {
                        schedule_next_burst(
                            &plans,
                            item.client,
                            done_bursts[item.client],
                            t_cursor,
                            &mut done_bursts,
                            &mut heap,
                            &mut seq,
                        );
                    }
                }
            }
            free_at[w] = t_cursor;
            wall_ns = wall_ns.max(t_cursor);
            push_ev(&mut heap, &mut seq, t_cursor, Ev::WorkerFree);
        }
    }
    if queue.front().is_some() {
        bail!("virtual run ended with queued requests (simulator bug)");
    }
    records.sort_by_key(|r| (r.client, r.index));
    let snapshot = EngineSnapshot::capture(&metrics);
    Ok(RunTrace { mode: "virtual", wall_ns, records, snapshot })
}

/// Deterministic event-heap push: `seq` tie-breaks equal timestamps in
/// insertion order, so heap ordering never consults [`Ev`] contents.
fn push_ev(heap: &mut BinaryHeap<Reverse<(u64, u64, Ev)>>, seq: &mut u64, t: u64, ev: Ev) {
    *seq += 1;
    heap.push(Reverse((t, *seq, ev)));
}

/// Closed-loop continuation: burst `burst` of `client` finished at `t`;
/// schedule the next planned burst think-time later.
fn schedule_next_burst(
    plans: &[Vec<super::arrivals::PlannedBurst>],
    client: usize,
    burst: usize,
    t: u64,
    done_bursts: &mut [usize],
    heap: &mut BinaryHeap<Reverse<(u64, u64, Ev)>>,
    seq: &mut u64,
) {
    done_bursts[client] = burst + 1;
    if let Some(next) = plans[client].get(burst + 1) {
        push_ev(heap, seq, t + next.gap_ns, Ev::Arrival { client, burst: burst + 1 });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::mix::MixSpace;

    fn tiny_mix(arrival_kind: &str) -> WorkloadMix {
        let mut space = MixSpace::default_space();
        space.arrivals = vec![arrival_kind.to_string()];
        space.clients = (2, 2);
        space.requests_per_client = (6, 6);
        let mut m = space.sample(5, 0);
        m.engine.workers = 2;
        m
    }

    #[test]
    fn virtual_runs_are_deterministic() {
        for kind in ["poisson", "deterministic", "closed-loop", "bursty"] {
            let mix = tiny_mix(kind);
            let a = run_virtual(&mix).unwrap();
            let b = run_virtual(&mix).unwrap();
            assert_eq!(a, b, "{kind} trace not reproducible");
            assert_eq!(a.records.len(), mix.total_requests(), "{kind}");
            // every request resolved, exactly once, in sorted order
            for (i, r) in a.records.iter().enumerate() {
                assert_eq!(r.client * mix.requests_per_client + r.index, i, "{kind}");
            }
        }
    }

    #[test]
    fn virtual_trace_reconciles_with_metrics() {
        let mix = tiny_mix("bursty");
        let trace = run_virtual(&mix).unwrap();
        let s = &trace.snapshot;
        let completed =
            trace.records.iter().filter(|r| r.outcome == Outcome::Completed).count() as u64;
        let shed = trace.records.iter().filter(|r| r.outcome == Outcome::Shed).count() as u64;
        assert_eq!(s.requests, completed + shed);
        assert_eq!(s.completed, completed);
        assert_eq!(s.errors, 0);
        assert_eq!(s.batched_requests + s.singleton_requests, completed);
        // no force-drain in the virtual policy
        assert_eq!(s.flushes.2, 0);
        // latencies are the cost-model service time at minimum
        assert!(trace
            .records
            .iter()
            .filter(|r| r.outcome == Outcome::Completed)
            .all(|r| r.latency_us > 0));
        assert!(trace.wall_ns > 0);
    }

    #[test]
    fn virtual_sheds_under_tiny_queue() {
        let mut mix = tiny_mix("poisson");
        mix.arrival = crate::workload::mix::ArrivalProcess::OpenPoisson { rate_rps: 1e9 };
        mix.requests_per_client = 50;
        mix.engine.batcher.max_queue = 2;
        mix.engine.batcher.max_batch = 2;
        let trace = run_virtual(&mix).unwrap();
        let shed = trace.records.iter().filter(|r| r.outcome == Outcome::Shed).count();
        assert!(shed > 0, "expected backpressure sheds at absurd rate");
        assert_eq!(
            trace.snapshot.requests as usize,
            trace.records.len(),
            "sheds still count as accepted requests"
        );
    }

    #[test]
    fn frames_respect_fill_and_seed() {
        let a = gen_frames(100, 0.5, 42);
        let b = gen_frames(100, 0.5, 42);
        assert_eq!(a, b);
        assert_eq!(a.len(), 100);
        assert!(a[..50].iter().all(|&v| v != 0.0));
        assert!(a[50..].iter().all(|&v| v == 0.0));
        let c = gen_frames(100, 0.5, 43);
        assert_ne!(a, c);
        // full fill leaves no padding
        assert!(gen_frames(10, 1.0, 1).iter().all(|&v| v != 0.0));
        // degenerate fills still produce at least one signal value
        assert_eq!(gen_frames(10, 0.001, 1).iter().filter(|&&v| v != 0.0).count(), 1);
    }
}

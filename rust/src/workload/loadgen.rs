//! Load-generator layer: replay a [`WorkloadMix`] against the serving
//! engine and record what every request experienced.
//!
//! Two modes share the same per-client plans ([`super::arrivals`]):
//!
//! - [`run_live`] drives the **real** [`Engine`] — worker threads,
//!   channels, the admission scheduler — with one OS thread per client.
//!   Wall-clock timing is real, so latencies are host-dependent; reply
//!   *contents* are not, and `verify` checks every completed reply
//!   bit-for-bit against an unbatched reference forward (safe because
//!   `Model::forward_batch` is pinned bit-identical to per-request
//!   forwards).
//! - [`run_virtual`] replays the plan on a virtual clock: a
//!   discrete-event loop that drives **the same
//!   [`Scheduler`](crate::coordinator::Scheduler) state machine the
//!   live engine runs** (admission, cost-model budget seals, EDF
//!   dequeue, typed sheds) with virtual timestamps and service times
//!   from the L2 cost model (`costmodel::serving_dispatch_ns`, ex5-big
//!   core).  Because the policy is shared code, flush decisions and
//!   shed counts mirror the live engine bit-exactly whenever live
//!   timing cannot influence them (see
//!   `tests/workload_harness.rs`).  Fully deterministic — same mix ⇒
//!   identical trace — which is what CI and the sweep figures run on.
//!
//! Both modes drive a real [`Metrics`] instance, so a report built from
//! the trace can reconcile record counts against engine counters
//! exactly ([`super::report::build_report`]).  Both accept a
//! [`FaultPlan`] (worker stalls, slow models) through the `_with`
//! variants; `FaultPlan::poison_reply_every` is a client-side fault the
//! scheduler battery injects directly and is ignored here.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::atomic::Ordering::Relaxed;
use std::time::{Duration, Instant};

use super::arrivals::client_plan;
use super::mix::WorkloadMix;
use crate::coordinator::{
    CostFn, Engine, FaultPlan, Metrics, ModelCounters, Scheduler, ShedReason, SubmitError,
};
use crate::costmodel::serving_dispatch_ns;
use crate::models::{
    CompiledModel, Model, ModelBuilder, ModelGraph, ModelRegistry, ModelStore, StoreError,
};
use crate::util::error::{anyhow, bail, Result};
use crate::util::rng::SplitMix64;

/// What happened to one planned request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// replied successfully
    Completed,
    /// shed at admission with a typed reason (queue backpressure or
    /// SLO admission control)
    Shed(ShedReason),
    /// replied with an error
    Error,
}

impl Outcome {
    /// Schema label (`completed`/`shed-queue-full`/`shed-over-budget`/
    /// `shed-cold-model`/`error`).
    pub fn name(&self) -> &'static str {
        match self {
            Outcome::Completed => "completed",
            Outcome::Shed(ShedReason::QueueFull) => "shed-queue-full",
            Outcome::Shed(ShedReason::OverBudget) => "shed-over-budget",
            Outcome::Shed(ShedReason::ColdModel) => "shed-cold-model",
            Outcome::Error => "error",
        }
    }

    /// Was this request shed at admission (either reason)?
    pub fn is_shed(&self) -> bool {
        matches!(self, Outcome::Shed(_))
    }
}

/// One request's observed fate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestRecord {
    /// issuing client
    pub client: usize,
    /// per-client request index (plan order)
    pub index: usize,
    /// index into `mix.models`
    pub model: usize,
    /// submission time, ns since run start
    pub submit_ns: u64,
    /// end-to-end latency in µs (0 for shed requests)
    pub latency_us: u64,
    /// what happened
    pub outcome: Outcome,
}

/// By-value snapshot of the engine's [`Metrics`] at run end.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EngineSnapshot {
    /// requests accepted at submission (sheds included)
    pub requests: u64,
    /// requests served to completion
    pub completed: u64,
    /// requests that failed
    pub errors: u64,
    /// requests served through a multi-request batched dispatch
    pub batched_requests: u64,
    /// requests served individually
    pub singleton_requests: u64,
    /// multi-request batched dispatches
    pub batched_dispatches: u64,
    /// `(full, budget, deadline, drained)` batch-flush counts
    pub flushes: (u64, u64, u64, u64),
    /// `(queue_full, over_budget, cold_model)` typed shed counts
    pub sheds: (u64, u64, u64),
    /// `(loads, evictions, swaps)` model-store counts
    pub store: (u64, u64, u64),
    /// shard-affinity dispatches past an earlier global deadline
    pub edf_inversions: u64,
    /// dispatches taken from outside the worker's home shard
    pub stolen_dispatches: u64,
    /// high-water per-model queue depth observed at admission
    pub max_queue_depth: u64,
    /// dispatch batch-size histogram, sorted by size
    pub batch_sizes: Vec<(u64, u64)>,
    /// per-model counters, sorted by registered name
    pub per_model: Vec<(String, ModelCounters)>,
}

impl EngineSnapshot {
    /// Capture the current counter values.
    pub fn capture(m: &Metrics) -> EngineSnapshot {
        EngineSnapshot {
            requests: m.requests.load(Relaxed),
            completed: m.completed.load(Relaxed),
            errors: m.errors.load(Relaxed),
            batched_requests: m.batched_requests.load(Relaxed),
            singleton_requests: m.singleton_requests.load(Relaxed),
            batched_dispatches: m.batched_dispatches.load(Relaxed),
            flushes: m.flush_counts(),
            sheds: m.shed_counts(),
            store: m.model_store_counts(),
            edf_inversions: m.edf_inversions.load(Relaxed),
            stolen_dispatches: m.stolen_dispatches.load(Relaxed),
            max_queue_depth: m.max_queue_depth.load(Relaxed),
            batch_sizes: m.batch_size_counts(),
            per_model: m.per_model_counters(),
        }
    }
}

/// Everything one run produced: per-request records plus the engine's
/// own counters, for reconciliation in the report layer.
#[derive(Debug, Clone, PartialEq)]
pub struct RunTrace {
    /// `"live"` or `"virtual"`
    pub mode: &'static str,
    /// run duration in ns (real for live, virtual-clock for virtual)
    pub wall_ns: u64,
    /// one record per planned request, sorted by `(client, index)`
    pub records: Vec<RequestRecord>,
    /// engine counters at run end
    pub snapshot: EngineSnapshot,
}

/// Deterministic request frames: the first `fill` fraction of the
/// model's fixed input window carries pseudo-random signal, the rest is
/// zero padding (a shorter utterance in a fixed-shape window — the
/// engine shape-validates, so the window itself never shrinks).
fn gen_frames(len: usize, fill: f64, seed: u64) -> Vec<f32> {
    let signal = ((fill * len as f64).round() as usize).clamp(1, len);
    let mut rng = SplitMix64::new(seed);
    let mut frames = vec![0.0f32; len];
    for f in frames.iter_mut().take(signal) {
        *f = rng.f64_in(-1.0, 1.0) as f32;
    }
    frames
}

/// Frame-seed stream id for `(client, index)` — disjoint from the plan
/// streams (which use bare client ids) via the high bit.
fn frame_stream(client: usize, index: usize) -> u64 {
    0x8000_0000_0000_0000 | ((client as u64) << 32) | index as u64
}

/// Build the mix's models: compiled instances for the engine roster
/// plus the graphs (for the virtual cost model and verify references).
fn build_models(mix: &WorkloadMix) -> Result<Vec<(ModelGraph, CompiledModel)>> {
    let mut out = Vec::with_capacity(mix.models.len());
    for m in &mix.models {
        let graph = ModelRegistry::global().build(
            &m.spec.model,
            m.spec.size,
            m.spec.variant,
            m.spec.seed,
        )?;
        let compiled = CompiledModel::compile(graph.clone())
            .map_err(|e| anyhow!("compiling {:?}: {e}", m.spec.name))?;
        out.push((graph, compiled));
    }
    Ok(out)
}

/// [`run_live_with`] with no injected faults.
pub fn run_live(mix: &WorkloadMix, verify: bool) -> Result<RunTrace> {
    run_live_with(mix, verify, &FaultPlan::default())
}

/// Replay `mix` against a live [`Engine`]: one thread per client, real
/// admission scheduler, real workers, with `faults` injected into the
/// engine.  With `verify`, every completed reply is checked
/// bit-for-bit against an unbatched reference forward of the same
/// frames.  Returns the trace with records sorted by `(client, index)`.
pub fn run_live_with(mix: &WorkloadMix, verify: bool, faults: &FaultPlan) -> Result<RunTrace> {
    mix.validate()?;
    let engine = Engine::new_with_faults(mix.engine, faults.clone());
    // register the roster and keep an independent reference instance
    // for verification.  Without a residency budget models register as
    // bare always-resident instances (the pre-store behavior); with
    // one they register lazily with a recompiling builder, are
    // warm-started in roster order (a deterministic initial LRU
    // state), and can be evicted/reloaded as the working set rotates —
    // re-admissions of evicted models shed with `ColdModel`.  The
    // virtual DES drives its own store through the identical sequence.
    let budgeted = mix.engine.store.budget_bytes.is_some();
    let refs: Vec<CompiledModel> = {
        let mut refs = Vec::with_capacity(mix.models.len());
        for (i, (graph, compiled)) in build_models(mix)?.into_iter().enumerate() {
            let name = &mix.models[i].spec.name;
            if budgeted {
                let hint = compiled.resident_bytes();
                let g = graph.clone();
                let builder: ModelBuilder = Box::new(move || {
                    CompiledModel::compile(g.clone())
                        .map(|m| std::sync::Arc::new(m) as std::sync::Arc<dyn Model>)
                        .map_err(|e| e.to_string())
                });
                engine
                    .register_model_lazy(name, hint, builder)
                    .map_err(|e| anyhow!("registering {name:?}: {e}"))?;
                // warm start: load in roster order
                engine
                    .model(name)
                    .ok_or_else(|| anyhow!("warm-starting {name:?} failed"))?;
            } else {
                engine
                    .register_model(name, compiled)
                    .map_err(|e| anyhow!("registering {name:?}: {e}"))?;
            }
            if mix.models[i].spec.pin {
                engine.pin_model(name).map_err(|e| anyhow!("pinning {name:?}: {e}"))?;
            }
            refs.push(
                CompiledModel::compile(graph)
                    .map_err(|e| anyhow!("compiling reference: {e}"))?,
            );
        }
        refs
    };
    let t0 = Instant::now();
    let results: Vec<Result<Vec<RequestRecord>>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..mix.clients)
            .map(|client| {
                let engine = &engine;
                let refs = &refs;
                scope.spawn(move || client_loop(mix, client, engine, refs, verify, t0))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("client thread panicked"))))
            .collect()
    });
    let wall_ns = t0.elapsed().as_nanos() as u64;
    let mut records = Vec::with_capacity(mix.total_requests());
    for r in results {
        records.extend(r?);
    }
    records.sort_by_key(|r| (r.client, r.index));
    // all replies are in hand: the snapshot is quiescent
    let snapshot = EngineSnapshot::capture(engine.metrics());
    engine.shutdown();
    Ok(RunTrace { mode: "live", wall_ns, records, snapshot })
}

/// One live client: walk the plan, submit bursts, collect replies.
fn client_loop(
    mix: &WorkloadMix,
    client: usize,
    engine: &Engine,
    refs: &[CompiledModel],
    verify: bool,
    t0: Instant,
) -> Result<Vec<RequestRecord>> {
    let plan = client_plan(mix, client);
    let open_loop = mix.arrival.is_open_loop();
    let mut records = Vec::with_capacity(mix.requests_per_client);
    // open loop: in-flight requests drained after all submissions
    let mut pending: Vec<(usize, usize, u64, Vec<f32>, std::sync::mpsc::Receiver<_>)> =
        Vec::new();
    let mut index = 0usize;
    // open loop tracks absolute arrival deadlines so sleep jitter does
    // not accumulate drift across bursts
    let mut t_next = Duration::ZERO;
    for burst in &plan {
        if open_loop {
            t_next += Duration::from_nanos(burst.gap_ns);
            let target = t0 + t_next;
            let now = Instant::now();
            if target > now {
                std::thread::sleep(target - now);
            }
        } else if burst.gap_ns > 0 {
            std::thread::sleep(Duration::from_nanos(burst.gap_ns));
        }
        let mut inline: Vec<(usize, usize, u64, Vec<f32>, std::sync::mpsc::Receiver<_>)> =
            Vec::new();
        for req in &burst.requests {
            let model = &mix.models[req.model];
            let len = refs[req.model].input_len();
            let frames = gen_frames(len, req.fill, SplitMix64::stream(
                mix.seed,
                frame_stream(client, index),
            ).next_u64());
            let submit_ns = t0.elapsed().as_nanos() as u64;
            match engine.try_submit(&model.spec.name, frames.clone()) {
                Ok(rx) => {
                    let slot = (index, req.model, submit_ns, frames, rx);
                    if open_loop {
                        pending.push(slot);
                    } else {
                        inline.push(slot);
                    }
                }
                Err(SubmitError::Rejected(rej)) => records.push(RequestRecord {
                    client,
                    index,
                    model: req.model,
                    submit_ns,
                    latency_us: 0,
                    outcome: Outcome::Shed(rej.reason),
                }),
                // the roster registers every mix model up front, so an
                // unknown-model refusal is a harness bug — but record
                // it as the error the engine counted it as
                Err(SubmitError::UnknownModel(_)) => records.push(RequestRecord {
                    client,
                    index,
                    model: req.model,
                    submit_ns,
                    latency_us: 0,
                    outcome: Outcome::Error,
                }),
            }
            index += 1;
        }
        // closed loop: the burst must complete before the think timer
        for slot in inline {
            records.push(collect_reply(client, slot, refs, verify)?);
        }
    }
    for slot in pending {
        records.push(collect_reply(client, slot, refs, verify)?);
    }
    Ok(records)
}

/// Wait for one reply and turn it into a record (verifying if asked).
fn collect_reply(
    client: usize,
    (index, model, submit_ns, frames, rx): (
        usize,
        usize,
        u64,
        Vec<f32>,
        std::sync::mpsc::Receiver<Result<crate::coordinator::Response>>,
    ),
    refs: &[CompiledModel],
    verify: bool,
) -> Result<RequestRecord> {
    let reply = rx.recv().map_err(|_| anyhow!("engine dropped request"))?;
    Ok(match reply {
        Ok(resp) => {
            if verify {
                let (expect, _) = refs[model].forward_timed(&frames);
                if resp.logits != expect {
                    bail!(
                        "reply mismatch: client {client} request {index}: batched \
                         logits differ from the per-request reference"
                    );
                }
            }
            RequestRecord {
                client,
                index,
                model,
                submit_ns,
                latency_us: (resp.total_ns / 1_000) as u64,
                outcome: Outcome::Completed,
            }
        }
        Err(_) => RequestRecord {
            client,
            index,
            model,
            submit_ns,
            latency_us: 0,
            outcome: Outcome::Error,
        },
    })
}

/// Discrete-event state: what kind of wake-up an event is.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Ev {
    /// client's burst arrives
    Arrival {
        /// issuing client
        client: usize,
        /// burst index in the client's plan
        burst: usize,
    },
    /// a worker finished its dispatch
    WorkerFree,
    /// a forming batch's seal-eligibility instant (deadline or budget)
    Wake,
}

/// One queued (virtual) request — the scheduler's payload.
#[derive(Debug, Clone, Copy)]
struct QItem {
    client: usize,
    index: usize,
}

/// [`run_virtual_with`] with no injected faults.
pub fn run_virtual(mix: &WorkloadMix) -> Result<RunTrace> {
    run_virtual_with(mix, &FaultPlan::default())
}

/// Replay `mix` on a virtual clock: a deterministic discrete-event
/// loop around the **live engine's own [`Scheduler`]** — admission,
/// cost-model budget seals, EDF/shard dequeue and typed sheds are the
/// same code the live engine runs, fed virtual timestamps — with
/// service times from the L2 cost model (`serving_dispatch_ns`: ex5-big
/// core, gem5 cache preset, ns = cycles / freq).  `faults` mirrors the
/// live plan: worker stalls delay each worker's first availability,
/// slow models add their extra latency to every dispatch.  Drives a
/// real [`Metrics`] instance so reports reconcile exactly.  Same mix ⇒
/// byte-identical trace.
pub fn run_virtual_with(mix: &WorkloadMix, faults: &FaultPlan) -> Result<RunTrace> {
    mix.validate()?;
    let models = build_models(mix)?;
    let metrics = std::sync::Arc::new(Metrics::default());
    let names: Vec<String> = mix.models.iter().map(|m| m.spec.name.clone()).collect();
    // the same service-time curve CompiledModel::dispatch_cost_ns
    // feeds the live engine's scheduler — shared brain, shared numbers
    let cost: CostFn = {
        let graphs: Vec<ModelGraph> = models.iter().map(|(g, _)| g.clone()).collect();
        let by_name: HashMap<String, usize> =
            names.iter().enumerate().map(|(i, n)| (n.clone(), i)).collect();
        Box::new(move |name, n| serving_dispatch_ns(&graphs[by_name[name]], n))
    };
    let mut sched: Scheduler<QItem> = Scheduler::new(mix.engine.sched, cost);
    for (i, name) in names.iter().enumerate() {
        let id = sched.register(name);
        debug_assert_eq!(id, i, "registration order must match mix order");
    }
    // a real ModelStore driven through the exact live-engine sequence
    // (same budget, same registration/warm-start order, same pins, a
    // pure-peek cost closure in both modes), so residency decisions —
    // which admissions shed cold, which entries evict — replay
    // bit-exactly.  The DES builder hands back the same Arc instead of
    // recompiling: only the *decisions* matter on a virtual clock.
    let budgeted = mix.engine.store.budget_bytes.is_some();
    let store = std::sync::Arc::new(ModelStore::new(
        mix.engine.store.budget_bytes.map(|b| b as usize),
    ));
    store.attach_metrics(metrics.clone());
    for (i, (_, compiled)) in models.into_iter().enumerate() {
        let name = &names[i];
        let instance: std::sync::Arc<dyn Model> = std::sync::Arc::new(compiled);
        if budgeted {
            let hint = instance.resident_bytes();
            let builder: ModelBuilder = {
                let a = instance.clone();
                Box::new(move || Ok(a.clone()))
            };
            store
                .register_lazy(name, hint, builder)
                .map_err(|e| anyhow!("registering {name:?}: {e}"))?;
            store
                .fetch(name)
                .map_err(|e| anyhow!("warm-starting {name:?}: {e}"))?;
        } else {
            store
                .register(name, instance)
                .map_err(|e| anyhow!("registering {name:?}: {e}"))?;
        }
        if mix.models[i].spec.pin {
            store.pin(name).map_err(|e| anyhow!("pinning {name:?}: {e}"))?;
        }
    }
    let fault_extra_ns: Vec<u64> = names
        .iter()
        .map(|n| faults.slow_for(n).map(|d| d.as_nanos() as u64).unwrap_or(0))
        .collect();

    let workers = mix.engine.workers.max(1);
    // a stalled worker pool becomes available only after the stall
    let stall_ns = faults.worker_stall.as_nanos() as u64;
    let mut free_at = vec![stall_ns; workers];

    let mut heap: BinaryHeap<Reverse<(u64, u64, Ev)>> = BinaryHeap::new();
    let mut seq = 0u64;
    if stall_ns > 0 {
        // sealed work queued entirely inside the stall window still
        // needs a wake-up the moment the pool recovers
        push_ev(&mut heap, &mut seq, stall_ns, Ev::WorkerFree);
    }

    let plans: Vec<_> = (0..mix.clients).map(|c| client_plan(mix, c)).collect();
    // per-client replay cursors (closed loop schedules burst n+1 only
    // after burst n fully completes)
    let mut next_index = vec![0usize; mix.clients];
    let mut outstanding = vec![0usize; mix.clients];
    let mut done_bursts = vec![0usize; mix.clients];

    let open_loop = mix.arrival.is_open_loop();
    if open_loop {
        // every arrival time is known up front
        for (client, plan) in plans.iter().enumerate() {
            let mut t = 0u64;
            for (b, burst) in plan.iter().enumerate() {
                t += burst.gap_ns;
                push_ev(&mut heap, &mut seq, t, Ev::Arrival { client, burst: b });
            }
        }
    } else {
        for (client, plan) in plans.iter().enumerate() {
            push_ev(&mut heap, &mut seq, plan[0].gap_ns, Ev::Arrival { client, burst: 0 });
        }
    }

    let mut records = Vec::with_capacity(mix.total_requests());
    let mut wall_ns = 0u64;

    while let Some(Reverse((t, _, ev))) = heap.pop() {
        wall_ns = wall_ns.max(t);
        if let Ev::Arrival { client, burst } = ev {
            metrics.mark_started();
            for req in &plans[client][burst].requests {
                let index = next_index[client];
                next_index[client] += 1;
                // mirror Engine::try_submit exactly: count the
                // request (sheds included), then the residency gate,
                // then scheduler admission
                metrics.requests.fetch_add(1, Relaxed);
                match store.admit(&names[req.model]) {
                    Ok(_) => {}
                    Err(StoreError::Cold(_)) => {
                        metrics.record_shed(&names[req.model], ShedReason::ColdModel);
                        records.push(RequestRecord {
                            client,
                            index,
                            model: req.model,
                            submit_ns: t,
                            latency_us: 0,
                            outcome: Outcome::Shed(ShedReason::ColdModel),
                        });
                        continue;
                    }
                    Err(e) => {
                        // unreachable for a registered roster, but
                        // mirror the live error accounting anyway
                        let _ = e;
                        metrics.errors.fetch_add(1, Relaxed);
                        records.push(RequestRecord {
                            client,
                            index,
                            model: req.model,
                            submit_ns: t,
                            latency_us: 0,
                            outcome: Outcome::Error,
                        });
                        continue;
                    }
                }
                match sched.submit(req.model, QItem { client, index }, t) {
                    Ok(a) => {
                        metrics.observe_queue_depth(&names[req.model], a.depth as u64);
                        outstanding[client] += 1;
                    }
                    Err(rej) => {
                        metrics.record_shed(&names[req.model], rej.reason);
                        records.push(RequestRecord {
                            client,
                            index,
                            model: req.model,
                            submit_ns: t,
                            latency_us: 0,
                            outcome: Outcome::Shed(rej.reason),
                        });
                    }
                }
            }
            // a fully-shed closed-loop burst completes immediately
            if !open_loop && outstanding[client] == 0 {
                schedule_next_burst(&plans, client, burst, t, &mut done_bursts, &mut heap, &mut seq);
            }
        }
        // dispatch sweep: every worker free at `t` drains its shard's
        // earliest-deadline sealed batch (stealing globally when the
        // shard is idle) — the same pop the live worker loop runs
        loop {
            sched.on_tick(t);
            let mut dispatched = false;
            for w in 0..workers {
                if free_at[w] > t {
                    continue;
                }
                let Some(d) = sched.pop(t, Some((w, workers))) else { continue };
                metrics.record_flush(d.reason);
                metrics.record_batch_size(d.entries.len() as u64);
                if d.stolen {
                    metrics.stolen_dispatches.fetch_add(1, Relaxed);
                }
                if d.inversion {
                    metrics.edf_inversions.fetch_add(1, Relaxed);
                }
                let n = d.entries.len();
                let name = &names[d.model];
                // mirror the live dispatch guard: counts the
                // transparent reload if the model was evicted between
                // admission and dispatch (dropped immediately — the
                // virtual forward is instantaneous in event time)
                let _ = store.begin_dispatch(name);
                let svc = sched.modeled_cost_ns(d.model, n) + fault_extra_ns[d.model];
                if n >= 2 {
                    metrics.record_batched_dispatch(name, n as u64);
                } else {
                    metrics.record_singleton(name, 1);
                }
                let done = t + svc;
                for (item, enq_ns) in &d.entries {
                    // queue wait measured at dispatch, plus the whole
                    // group's forward — process_group semantics
                    let latency_us = ((t - enq_ns) + svc) / 1_000;
                    metrics.observe_latency_for(name, latency_us);
                    records.push(RequestRecord {
                        client: item.client,
                        index: item.index,
                        model: d.model,
                        submit_ns: *enq_ns,
                        latency_us,
                        outcome: Outcome::Completed,
                    });
                    // closed loop: a finished burst unblocks its client
                    outstanding[item.client] -= 1;
                    if !open_loop && outstanding[item.client] == 0 {
                        schedule_next_burst(
                            &plans,
                            item.client,
                            done_bursts[item.client],
                            done,
                            &mut done_bursts,
                            &mut heap,
                            &mut seq,
                        );
                    }
                }
                free_at[w] = done;
                wall_ns = wall_ns.max(done);
                push_ev(&mut heap, &mut seq, done, Ev::WorkerFree);
                dispatched = true;
            }
            if !dispatched {
                break;
            }
        }
        // nothing dispatchable: if batches are still forming, wake at
        // their next seal-eligibility instant (deadline or budget)
        if sched.has_forming() {
            if let Some(tw) = sched.next_wakeup(t) {
                push_ev(&mut heap, &mut seq, tw, Ev::Wake);
            }
        }
    }
    if !sched.is_empty() {
        bail!("virtual run ended with queued requests (simulator bug)");
    }
    records.sort_by_key(|r| (r.client, r.index));
    let snapshot = EngineSnapshot::capture(&metrics);
    Ok(RunTrace { mode: "virtual", wall_ns, records, snapshot })
}

/// Deterministic event-heap push: `seq` tie-breaks equal timestamps in
/// insertion order, so heap ordering never consults [`Ev`] contents.
fn push_ev(heap: &mut BinaryHeap<Reverse<(u64, u64, Ev)>>, seq: &mut u64, t: u64, ev: Ev) {
    *seq += 1;
    heap.push(Reverse((t, *seq, ev)));
}

/// Closed-loop continuation: burst `burst` of `client` finished at `t`;
/// schedule the next planned burst think-time later.
fn schedule_next_burst(
    plans: &[Vec<super::arrivals::PlannedBurst>],
    client: usize,
    burst: usize,
    t: u64,
    done_bursts: &mut [usize],
    heap: &mut BinaryHeap<Reverse<(u64, u64, Ev)>>,
    seq: &mut u64,
) {
    done_bursts[client] = burst + 1;
    if let Some(next) = plans[client].get(burst + 1) {
        push_ev(heap, seq, t + next.gap_ns, Ev::Arrival { client, burst: burst + 1 });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::mix::MixSpace;

    fn tiny_mix(arrival_kind: &str) -> WorkloadMix {
        let mut space = MixSpace::default_space();
        space.arrivals = vec![arrival_kind.to_string()];
        space.clients = (2, 2);
        space.requests_per_client = (6, 6);
        let mut m = space.sample(5, 0);
        m.engine.workers = 2;
        m
    }

    #[test]
    fn virtual_runs_are_deterministic() {
        for kind in ["poisson", "deterministic", "closed-loop", "bursty"] {
            let mix = tiny_mix(kind);
            let a = run_virtual(&mix).unwrap();
            let b = run_virtual(&mix).unwrap();
            assert_eq!(a, b, "{kind} trace not reproducible");
            assert_eq!(a.records.len(), mix.total_requests(), "{kind}");
            // every request resolved, exactly once, in sorted order
            for (i, r) in a.records.iter().enumerate() {
                assert_eq!(r.client * mix.requests_per_client + r.index, i, "{kind}");
            }
        }
    }

    #[test]
    fn virtual_trace_reconciles_with_metrics() {
        let mix = tiny_mix("bursty");
        let trace = run_virtual(&mix).unwrap();
        let s = &trace.snapshot;
        let completed =
            trace.records.iter().filter(|r| r.outcome == Outcome::Completed).count() as u64;
        let shed = trace.records.iter().filter(|r| r.outcome.is_shed()).count() as u64;
        assert_eq!(s.requests, completed + shed);
        assert_eq!(s.completed, completed);
        assert_eq!(s.errors, 0);
        assert_eq!(s.batched_requests + s.singleton_requests, completed);
        // typed sheds reconcile with the records
        assert_eq!(s.sheds.0 + s.sheds.1 + s.sheds.2, shed);
        // no force-drain in the virtual policy
        assert_eq!(s.flushes.3, 0);
        // the batch-size histogram covers every served request
        let sized: u64 = s.batch_sizes.iter().map(|&(sz, n)| sz * n).sum();
        assert_eq!(sized, completed);
        // latencies are the cost-model service time at minimum
        assert!(trace
            .records
            .iter()
            .filter(|r| r.outcome == Outcome::Completed)
            .all(|r| r.latency_us > 0));
        assert!(trace.wall_ns > 0);
    }

    #[test]
    fn virtual_sheds_under_tiny_queue_are_typed() {
        let mut mix = tiny_mix("poisson");
        mix.arrival = crate::workload::mix::ArrivalProcess::OpenPoisson { rate_rps: 1e9 };
        mix.requests_per_client = 50;
        mix.engine.sched.max_queue = 2;
        mix.engine.sched.max_batch = 2;
        let trace = run_virtual(&mix).unwrap();
        let shed = trace.records.iter().filter(|r| r.outcome.is_shed()).count();
        assert!(shed > 0, "expected backpressure sheds at absurd rate");
        assert!(
            trace
                .records
                .iter()
                .any(|r| r.outcome == Outcome::Shed(ShedReason::QueueFull)),
            "queue-full sheds carry their reason"
        );
        assert_eq!(
            trace.snapshot.requests as usize,
            trace.records.len(),
            "sheds still count as accepted requests"
        );
        assert_eq!(
            trace.snapshot.sheds.0 + trace.snapshot.sheds.1 + trace.snapshot.sheds.2,
            shed as u64,
            "typed shed counters reconcile"
        );
    }

    #[test]
    fn virtual_worker_stall_fault_delays_first_dispatch() {
        let mix = tiny_mix("deterministic");
        let base = run_virtual(&mix).unwrap();
        let stalled = run_virtual_with(
            &mix,
            &FaultPlan { worker_stall: Duration::from_millis(5), ..FaultPlan::default() },
        )
        .unwrap();
        // all requests still resolve exactly once under the fault
        assert_eq!(stalled.records.len(), mix.total_requests());
        // and the virtual clock reflects the injected stall
        assert!(
            stalled.wall_ns >= 5_000_000 && stalled.wall_ns >= base.wall_ns,
            "stall must push completions past 5ms (got {} vs base {})",
            stalled.wall_ns,
            base.wall_ns
        );
    }

    #[test]
    fn frames_respect_fill_and_seed() {
        let a = gen_frames(100, 0.5, 42);
        let b = gen_frames(100, 0.5, 42);
        assert_eq!(a, b);
        assert_eq!(a.len(), 100);
        assert!(a[..50].iter().all(|&v| v != 0.0));
        assert!(a[50..].iter().all(|&v| v == 0.0));
        let c = gen_frames(100, 0.5, 43);
        assert_ne!(a, c);
        // full fill leaves no padding
        assert!(gen_frames(10, 1.0, 1).iter().all(|&v| v != 0.0));
        // degenerate fills still produce at least one signal value
        assert_eq!(gen_frames(10, 0.001, 1).iter().filter(|&&v| v != 0.0).count(), 1);
    }
}

//! Report layer: aggregate one run's [`RunTrace`] into a [`MixReport`]
//! and render sweeps as the `bench-serve/v3` document
//! (`BENCH_serve.json`), sibling of `bench-kernels/v1` and
//! `bench-gemm/v2` (`util::bench`).  v2 (over v1) carries the admission
//! scheduler's policy signals: cost-model `Budget` flushes, typed shed
//! splits, queue-occupancy high-water marks and EDF inversions/steals.
//! v3 (over v2) carries the model store's residency signals
//! (DESIGN.md §14): the cold-model shed split and the engine-wide
//! load/eviction/hot-swap counts, all reconciled against the engine's
//! counters like every other field.
//!
//! Percentiles here are **exact** nearest-rank over the raw per-request
//! latencies — the sort oracle — not the bucketed approximation the
//! always-on [`Metrics`](crate::coordinator::Metrics) histogram gives;
//! [`build_report`] cross-checks every count against the engine's own
//! counters and refuses to produce a report that does not reconcile.

use super::loadgen::{Outcome, RunTrace};
use super::mix::WorkloadMix;
use crate::coordinator::ShedReason;
use crate::util::bench::json_escape;
use crate::util::error::{bail, Result};

/// Exact nearest-rank percentile: the smallest sample such that at
/// least `q·n` samples are ≤ it.  `samples` must be sorted ascending.
fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((sorted.len() as f64) * q).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Per-model aggregation inside one [`MixReport`].
#[derive(Debug, Clone, PartialEq)]
pub struct ModelLine {
    /// registered model name
    pub name: String,
    /// requests completed for this model
    pub completed: u64,
    /// requests errored for this model
    pub errors: u64,
    /// requests shed from this model's admission queue (both reasons)
    pub shed: u64,
    /// served through a multi-request batched dispatch
    pub batched_requests: u64,
    /// served individually
    pub singleton_requests: u64,
    /// multi-request dispatches
    pub batched_dispatches: u64,
    /// high-water queue depth observed at admission
    pub max_queue_depth: u64,
    /// exact nearest-rank p50 over this model's completed requests (µs)
    pub p50_us: u64,
    /// exact nearest-rank p99 (µs)
    pub p99_us: u64,
    /// mean latency (µs)
    pub mean_us: f64,
}

/// One mix's aggregated outcome — a row of `BENCH_serve.json` and of
/// the `fig-serve` tables.
#[derive(Debug, Clone, PartialEq)]
pub struct MixReport {
    /// mix name
    pub mix: String,
    /// mix seed (replay handle)
    pub seed: u64,
    /// `"live"` or `"virtual"`
    pub mode: String,
    /// arrival-process description (`ArrivalProcess::describe`)
    pub arrival: String,
    /// load-generating clients
    pub clients: usize,
    /// requests issued (sheds included)
    pub issued: u64,
    /// requests completed
    pub completed: u64,
    /// requests errored
    pub errors: u64,
    /// requests shed at admission (both reasons)
    pub shed: u64,
    /// sheds typed [`ShedReason::QueueFull`]
    pub shed_queue_full: u64,
    /// sheds typed [`ShedReason::OverBudget`]
    pub shed_over_budget: u64,
    /// sheds typed [`ShedReason::ColdModel`] (residency misses)
    pub shed_cold_model: u64,
    /// exact nearest-rank p50 latency (µs)
    pub p50_us: u64,
    /// exact nearest-rank p95 latency (µs)
    pub p95_us: u64,
    /// exact nearest-rank p99 latency (µs)
    pub p99_us: u64,
    /// worst completed-request latency (µs)
    pub max_us: u64,
    /// mean latency over completed requests (µs)
    pub mean_us: f64,
    /// completed requests per second of run wall time
    pub throughput_rps: f64,
    /// run duration (ms; virtual-clock ms in virtual mode)
    pub wall_ms: f64,
    /// requests served through multi-request batched dispatches
    pub batched_requests: u64,
    /// requests served individually
    pub singleton_requests: u64,
    /// multi-request batched dispatches
    pub batched_dispatches: u64,
    /// `(full, budget, deadline, drained)` batch-flush counts
    pub flushes: (u64, u64, u64, u64),
    /// shard-affinity dispatches past an earlier global EDF deadline
    pub edf_inversions: u64,
    /// dispatches a worker took from outside its home shard
    pub stolen_dispatches: u64,
    /// engine-wide high-water per-model queue depth
    pub max_queue_depth: u64,
    /// model-store cold/eager loads over the run
    pub store_loads: u64,
    /// model-store LRU evictions over the run
    pub store_evictions: u64,
    /// model-store atomic hot-swaps over the run
    pub store_swaps: u64,
    /// per-model breakdown, in mix composition order
    pub per_model: Vec<ModelLine>,
}

/// Aggregate a run into a report, reconciling every count against the
/// engine's [`Metrics`](crate::coordinator::Metrics) snapshot — a
/// mismatch means a request was dropped or double-counted somewhere,
/// and is an error, not a report.
pub fn build_report(mix: &WorkloadMix, trace: &RunTrace) -> Result<MixReport> {
    let issued = trace.records.len() as u64;
    if issued != mix.total_requests() as u64 {
        bail!(
            "trace holds {issued} records but the mix plans {} requests",
            mix.total_requests()
        );
    }
    let count = |o: Outcome| trace.records.iter().filter(|r| r.outcome == o).count() as u64;
    let completed = count(Outcome::Completed);
    let errors = count(Outcome::Error);
    let shed_queue_full = count(Outcome::Shed(ShedReason::QueueFull));
    let shed_over_budget = count(Outcome::Shed(ShedReason::OverBudget));
    let shed_cold_model = count(Outcome::Shed(ShedReason::ColdModel));
    let shed = shed_queue_full + shed_over_budget + shed_cold_model;
    let s = &trace.snapshot;
    if s.requests != issued {
        bail!("engine accepted {} requests but the trace issued {issued}", s.requests);
    }
    if s.completed != completed {
        bail!("engine completed {} but the trace records {completed}", s.completed);
    }
    if s.errors != errors {
        bail!("engine errored {} but the trace records {errors}", s.errors);
    }
    if s.sheds != (shed_queue_full, shed_over_budget, shed_cold_model) {
        bail!(
            "engine shed {:?} (queue-full, over-budget, cold-model) but the trace records \
             ({shed_queue_full}, {shed_over_budget}, {shed_cold_model})",
            s.sheds
        );
    }
    if s.batched_requests + s.singleton_requests != completed + errors {
        bail!(
            "dispatch split {}+{} does not cover the {} worker-handled requests",
            s.batched_requests,
            s.singleton_requests,
            completed + errors
        );
    }
    // per-model reconciliation: the trace's per-model outcome counts
    // must match the engine's per-model counters exactly
    let mut per_model = Vec::with_capacity(mix.models.len());
    for (mi, m) in mix.models.iter().enumerate() {
        let name = &m.spec.name;
        let counters = s
            .per_model
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, c)| *c)
            .unwrap_or_default();
        let mut lat: Vec<u64> = trace
            .records
            .iter()
            .filter(|r| r.model == mi && r.outcome == Outcome::Completed)
            .map(|r| r.latency_us)
            .collect();
        lat.sort_unstable();
        if counters.completed != lat.len() as u64 {
            bail!(
                "model {name:?}: engine completed {} but the trace records {}",
                counters.completed,
                lat.len()
            );
        }
        let model_errors = trace
            .records
            .iter()
            .filter(|r| r.model == mi && r.outcome == Outcome::Error)
            .count() as u64;
        if counters.errors != model_errors {
            bail!(
                "model {name:?}: engine errored {} but the trace records {model_errors}",
                counters.errors
            );
        }
        let model_shed = trace
            .records
            .iter()
            .filter(|r| r.model == mi && r.outcome.is_shed())
            .count() as u64;
        if counters.sheds_queue_full + counters.sheds_over_budget + counters.sheds_cold_model
            != model_shed
        {
            bail!(
                "model {name:?}: engine shed {}+{}+{} but the trace records {model_shed}",
                counters.sheds_queue_full,
                counters.sheds_over_budget,
                counters.sheds_cold_model
            );
        }
        let mean_us = if lat.is_empty() {
            0.0
        } else {
            lat.iter().sum::<u64>() as f64 / lat.len() as f64
        };
        per_model.push(ModelLine {
            name: name.clone(),
            completed: counters.completed,
            errors: counters.errors,
            shed: model_shed,
            batched_requests: counters.batched_requests,
            singleton_requests: counters.singleton_requests,
            batched_dispatches: counters.batched_dispatches,
            max_queue_depth: counters.max_queue_depth,
            p50_us: percentile(&lat, 0.50),
            p99_us: percentile(&lat, 0.99),
            mean_us,
        });
    }
    let mut lat: Vec<u64> = trace
        .records
        .iter()
        .filter(|r| r.outcome == Outcome::Completed)
        .map(|r| r.latency_us)
        .collect();
    lat.sort_unstable();
    let mean_us = if lat.is_empty() {
        0.0
    } else {
        lat.iter().sum::<u64>() as f64 / lat.len() as f64
    };
    let wall_s = trace.wall_ns as f64 / 1e9;
    Ok(MixReport {
        mix: mix.name.clone(),
        seed: mix.seed,
        mode: trace.mode.to_string(),
        arrival: mix.arrival.describe(),
        clients: mix.clients,
        issued,
        completed,
        errors,
        shed,
        shed_queue_full,
        shed_over_budget,
        shed_cold_model,
        p50_us: percentile(&lat, 0.50),
        p95_us: percentile(&lat, 0.95),
        p99_us: percentile(&lat, 0.99),
        max_us: lat.last().copied().unwrap_or(0),
        mean_us,
        throughput_rps: if wall_s > 0.0 { completed as f64 / wall_s } else { 0.0 },
        wall_ms: trace.wall_ns as f64 / 1e6,
        batched_requests: s.batched_requests,
        singleton_requests: s.singleton_requests,
        batched_dispatches: s.batched_dispatches,
        flushes: s.flushes,
        edf_inversions: s.edf_inversions,
        stolen_dispatches: s.stolen_dispatches,
        max_queue_depth: s.max_queue_depth,
        store_loads: s.store.0,
        store_evictions: s.store.1,
        store_swaps: s.store.2,
        per_model,
    })
}

/// Render the `BENCH_serve.json` document (schema `bench-serve/v3`).
/// Provenance follows the repo convention (`util::bench`): `source`
/// says how the numbers were obtained (`"live"` from a real engine run,
/// `"virtual-costmodel"` from the virtual clock), `host` and `note` are
/// free-form.
pub fn serve_records_json(
    source: &str,
    host: &str,
    note: &str,
    reports: &[MixReport],
) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": \"bench-serve/v3\",\n");
    out.push_str(&format!("  \"source\": \"{}\",\n", json_escape(source)));
    out.push_str(&format!("  \"host\": \"{}\",\n", json_escape(host)));
    out.push_str(&format!("  \"note\": \"{}\",\n", json_escape(note)));
    out.push_str("  \"records\": [\n");
    for (i, r) in reports.iter().enumerate() {
        let models: Vec<String> = r
            .per_model
            .iter()
            .map(|m| {
                format!(
                    "{{\"name\": \"{}\", \"completed\": {}, \"errors\": {}, \"shed\": {}, \
                     \"batched_requests\": {}, \"singleton_requests\": {}, \
                     \"batched_dispatches\": {}, \"max_queue_depth\": {}, \
                     \"p50_us\": {}, \"p99_us\": {}, \"mean_us\": {:.1}}}",
                    json_escape(&m.name),
                    m.completed,
                    m.errors,
                    m.shed,
                    m.batched_requests,
                    m.singleton_requests,
                    m.batched_dispatches,
                    m.max_queue_depth,
                    m.p50_us,
                    m.p99_us,
                    m.mean_us,
                )
            })
            .collect();
        out.push_str(&format!(
            "    {{\"mix\": \"{}\", \"seed\": {}, \"mode\": \"{}\", \"arrival\": \"{}\", \
             \"clients\": {}, \"issued\": {}, \"completed\": {}, \"errors\": {}, \
             \"shed\": {}, \"shed_queue_full\": {}, \"shed_over_budget\": {}, \
             \"shed_cold_model\": {}, \
             \"p50_us\": {}, \"p95_us\": {}, \"p99_us\": {}, \"max_us\": {}, \
             \"mean_us\": {:.1}, \"throughput_rps\": {:.1}, \"wall_ms\": {:.3}, \
             \"batched_requests\": {}, \"singleton_requests\": {}, \"batched_dispatches\": {}, \
             \"flushes_full\": {}, \"flushes_budget\": {}, \"flushes_deadline\": {}, \
             \"flushes_drained\": {}, \"edf_inversions\": {}, \"stolen_dispatches\": {}, \
             \"max_queue_depth\": {}, \"store_loads\": {}, \"store_evictions\": {}, \
             \"store_swaps\": {}, \"models\": [{}]}}{}\n",
            json_escape(&r.mix),
            r.seed,
            json_escape(&r.mode),
            json_escape(&r.arrival),
            r.clients,
            r.issued,
            r.completed,
            r.errors,
            r.shed,
            r.shed_queue_full,
            r.shed_over_budget,
            r.shed_cold_model,
            r.p50_us,
            r.p95_us,
            r.p99_us,
            r.max_us,
            r.mean_us,
            r.throughput_rps,
            r.wall_ms,
            r.batched_requests,
            r.singleton_requests,
            r.batched_dispatches,
            r.flushes.0,
            r.flushes.1,
            r.flushes.2,
            r.flushes.3,
            r.edf_inversions,
            r.stolen_dispatches,
            r.max_queue_depth,
            r.store_loads,
            r.store_evictions,
            r.store_swaps,
            models.join(", "),
            if i + 1 < reports.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write [`serve_records_json`] to `path` (repo convention:
/// `BENCH_serve.json` at the repository root).
pub fn write_serve_json(
    path: &str,
    source: &str,
    host: &str,
    note: &str,
    reports: &[MixReport],
) -> std::io::Result<()> {
    std::fs::write(path, serve_records_json(source, host, note, reports))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use crate::workload::loadgen::run_virtual;
    use crate::workload::mix::MixSpace;

    #[test]
    fn percentile_matches_sort_oracle_semantics() {
        // nearest-rank over a known set: p50 of 1..=10 is the 5th value
        let v: Vec<u64> = (1..=10).collect();
        assert_eq!(percentile(&v, 0.50), 5);
        assert_eq!(percentile(&v, 0.95), 10);
        assert_eq!(percentile(&v, 0.99), 10);
        assert_eq!(percentile(&v, 1.0), 10);
        assert_eq!(percentile(&[], 0.5), 0);
        assert_eq!(percentile(&[7], 0.5), 7);
        // 100 distinct values: pXX picks index ceil(q*100)-1
        let v: Vec<u64> = (0..100).map(|i| i * 10).collect();
        assert_eq!(percentile(&v, 0.50), 490);
        assert_eq!(percentile(&v, 0.95), 940);
        assert_eq!(percentile(&v, 0.99), 980);
    }

    #[test]
    fn report_reconciles_and_serializes() {
        let mut space = MixSpace::default_space();
        space.arrivals = vec!["bursty".to_string()];
        space.clients = (2, 2);
        space.requests_per_client = (8, 8);
        let mix = space.sample(21, 0);
        let trace = run_virtual(&mix).unwrap();
        let report = build_report(&mix, &trace).unwrap();
        assert_eq!(report.issued, mix.total_requests() as u64);
        assert_eq!(report.completed + report.errors + report.shed, report.issued);
        assert_eq!(
            report.shed,
            report.shed_queue_full + report.shed_over_budget + report.shed_cold_model
        );
        assert_eq!(report.mode, "virtual");
        assert!(report.p50_us <= report.p95_us && report.p95_us <= report.p99_us);
        assert!(report.p99_us <= report.max_us);
        assert_eq!(report.per_model.len(), mix.models.len());
        let per_model_total: u64 = report.per_model.iter().map(|m| m.completed).sum();
        assert_eq!(per_model_total, report.completed);
        let per_model_shed: u64 = report.per_model.iter().map(|m| m.shed).sum();
        assert_eq!(per_model_shed, report.shed);
        // the document parses back with the declared schema
        let doc = serve_records_json("virtual-costmodel", "test", "unit test", &[report]);
        let j = Json::parse(&doc).unwrap();
        assert_eq!(j.get("schema").and_then(Json::as_str), Some("bench-serve/v3"));
        let recs = j.get("records").and_then(Json::as_arr).unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].get("mix").and_then(Json::as_str), Some("mix_000"));
        assert!(recs[0].get("p99_us").and_then(Json::as_f64).is_some());
        assert!(recs[0].get("flushes_budget").and_then(Json::as_f64).is_some());
        assert!(recs[0].get("shed_queue_full").and_then(Json::as_f64).is_some());
        assert!(recs[0].get("shed_cold_model").and_then(Json::as_f64).is_some());
        assert!(recs[0].get("edf_inversions").and_then(Json::as_f64).is_some());
        assert!(recs[0].get("max_queue_depth").and_then(Json::as_f64).is_some());
        assert!(recs[0].get("store_loads").and_then(Json::as_f64).is_some());
        assert!(recs[0].get("store_evictions").and_then(Json::as_f64).is_some());
        assert!(recs[0].get("store_swaps").and_then(Json::as_f64).is_some());
        assert_eq!(
            recs[0].get("models").and_then(Json::as_arr).unwrap().len(),
            mix.models.len()
        );
    }

    #[test]
    fn report_rejects_tampered_traces() {
        let mut space = MixSpace::default_space();
        space.clients = (1, 1);
        space.requests_per_client = (4, 4);
        let mix = space.sample(3, 0);
        let good = run_virtual(&mix).unwrap();
        // dropping a record breaks the issued-count reconciliation
        let mut t = good.clone();
        t.records.pop();
        assert!(build_report(&mix, &t).is_err());
        // inflating an engine counter breaks the completed reconciliation
        let mut t = good.clone();
        t.snapshot.completed += 1;
        assert!(build_report(&mix, &t).is_err());
        // an unrecorded typed shed breaks the shed reconciliation
        let mut t = good.clone();
        t.snapshot.sheds.1 += 1;
        assert!(build_report(&mix, &t).is_err());
        // flipping a record's model breaks the per-model reconciliation
        if mix.models.len() > 1 {
            let mut t = good.clone();
            t.records[0].model = (t.records[0].model + 1) % mix.models.len();
            assert!(build_report(&mix, &t).is_err());
        }
        // the untouched trace still reconciles
        assert!(build_report(&mix, &good).is_ok());
    }
}

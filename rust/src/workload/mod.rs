//! Scenario-mix workload harness (DESIGN.md §11): declarative load
//! sweeps for the serving engine.
//!
//! The paper's headline is that *which scenarios you run decides which
//! method wins* (speedups swing 0.96×–6.7× with layer shapes); the
//! serving analogue is that which **traffic** you replay decides how
//! the engine's batching, routing and admission policies score.  This
//! subsystem makes traffic a declarative artifact instead of test
//! code, in four layers (the parsimon-eval idiom from ROADMAP.md):
//!
//! 1. **spec** ([`mix`]) — [`WorkloadMix`]: one JSON file describing a
//!    scenario (arrival process, model composition, burst and
//!    sequence-fill distributions, client count, seed, engine config),
//!    plus [`MixSpace`]: per-axis ranges a sweep samples from.
//! 2. **sampler** ([`MixSpace::sample`]) — seeded SplitMix64 sampling
//!    of N concrete mixes from a space (`fullpack workload gen-mixes`);
//!    same seed ⇒ byte-identical mix files.
//! 3. **loadgen** ([`loadgen`]) — multi-client replay of a mix against
//!    the **live** [`crate::coordinator::Engine`] (real threads, real
//!    channels, the real admission scheduler) in open- and closed-loop
//!    modes, plus a virtual-clock discrete-event mode that drives the
//!    *same* [`crate::coordinator::Scheduler`] state machine with
//!    cost-model service times — flush decisions and typed shed counts
//!    mirror the live policy bit-exactly on timing-insensitive mixes.
//!    Both modes accept a [`crate::coordinator::FaultPlan`] through the
//!    `_with` variants (worker stalls, slow models) for degradation
//!    testing.
//! 4. **report** ([`report`]) — per-mix aggregation into exact
//!    p50/p95/p99, throughput, typed shed/error counts, flush-reason
//!    and dispatch splits, queue occupancy and EDF inversions,
//!    reconciled against [`crate::coordinator::Metrics`] and emitted
//!    as the `bench-serve/v3` schema (`BENCH_serve.json`), model-store
//!    residency counters (cold sheds, loads/evictions/swaps) included.
#![warn(missing_docs)]

pub mod arrivals;
pub mod loadgen;
pub mod mix;
pub mod report;

pub use arrivals::{client_plan, PlannedBurst, PlannedRequest};
pub use loadgen::{
    run_live, run_live_with, run_virtual, run_virtual_with, EngineSnapshot, Outcome,
    RequestRecord, RunTrace,
};
pub use mix::{ArrivalProcess, Dist, MixModel, MixSpace, WorkloadMix};
pub use report::{build_report, serve_records_json, write_serve_json, MixReport, ModelLine};

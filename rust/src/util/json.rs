//! Minimal JSON parser for the artifact manifest (serde is not
//! available offline — DESIGN.md §7).  Supports the full JSON grammar
//! the manifest uses: objects, arrays, strings (with escapes), numbers,
//! booleans and null.

use std::collections::BTreeMap;

#[derive(Debug)]
pub enum JsonError {
    Eof(usize),
    Unexpected(char, usize),
    BadNumber(usize),
    BadEscape(usize),
    Trailing(usize),
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::Eof(i) => write!(f, "unexpected end of input at byte {i}"),
            JsonError::Unexpected(c, i) => write!(f, "unexpected character {c:?} at byte {i}"),
            JsonError::BadNumber(i) => write!(f, "invalid number at byte {i}"),
            JsonError::BadEscape(i) => write!(f, "invalid escape at byte {i}"),
            JsonError::Trailing(i) => write!(f, "trailing data at byte {i}"),
        }
    }
}

impl std::error::Error for JsonError {}

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(JsonError::Trailing(p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8, JsonError> {
        self.b.get(self.i).copied().ok_or(JsonError::Eof(self.i))
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        let got = self.peek()?;
        if got != c {
            return Err(JsonError::Unexpected(got as char, self.i));
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(JsonError::Unexpected(c as char, self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(JsonError::Unexpected(self.b[self.i] as char, self.i))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            m.insert(key, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => return Err(JsonError::Unexpected(c as char, self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => return Err(JsonError::Unexpected(c as char, self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(JsonError::BadEscape(self.i));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| JsonError::BadEscape(self.i))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError::BadEscape(self.i))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(JsonError::BadEscape(self.i)),
                    }
                }
                _ => {
                    // collect the full UTF-8 sequence
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(JsonError::Eof(start));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| JsonError::BadEscape(start))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or(JsonError::BadNumber(start))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let j = Json::parse(
            r#"{"version": 1, "vl": 16,
                "artifacts": [{"name": "gemv_w4a8", "inputs":
                  [{"name": "weights", "dtype": "u8", "shape": [256, 128]}],
                  "ok": true, "x": null}]}"#,
        )
        .unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        let a0 = &arts[0];
        assert_eq!(a0.get("name").unwrap().as_str(), Some("gemv_w4a8"));
        let shape = a0.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(256));
        assert_eq!(a0.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(a0.get("x"), Some(&Json::Null));
    }

    #[test]
    fn strings_with_escapes() {
        let j = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\ndA"));
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e2").unwrap().as_f64(), Some(-150.0));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn nested_and_empty() {
        let j = Json::parse(r#"{"a": [], "b": {}, "c": [[1], [2, 3]]}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 0);
        assert_eq!(
            j.get("c").unwrap().as_arr().unwrap()[1].as_arr().unwrap()[1].as_f64(),
            Some(3.0)
        );
    }
}

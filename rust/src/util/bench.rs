//! Criterion-less benchmark harness (criterion is unavailable offline —
//! DESIGN.md §7): warmup + timed iterations, robust statistics, and the
//! fixed-width table printer the figure harnesses share.

use std::time::Instant;

/// Result of one measurement: nanoseconds per iteration.
#[derive(Debug, Clone, Copy)]
pub struct Measurement {
    pub median_ns: f64,
    pub mean_ns: f64,
    /// median absolute deviation (robust spread)
    pub mad_ns: f64,
    pub iters: usize,
}

impl Measurement {
    pub fn micros(&self) -> f64 {
        self.median_ns / 1e3
    }

    pub fn millis(&self) -> f64 {
        self.median_ns / 1e6
    }
}

/// Benchmark a closure: `warmup` untimed runs, then time per-iteration
/// until `min_total_ms` of samples or `max_iters`, whichever first.
pub fn bench<F: FnMut()>(mut f: F, warmup: usize, min_total_ms: u64, max_iters: usize) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::new();
    let budget = std::time::Duration::from_millis(min_total_ms);
    let start = Instant::now();
    while (start.elapsed() < budget && samples.len() < max_iters) || samples.len() < 3 {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    summarize(&mut samples)
}

fn summarize(samples: &mut [f64]) -> Measurement {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Measurement {
        median_ns: median,
        mean_ns: mean,
        mad_ns: devs[devs.len() / 2],
        iters: samples.len(),
    }
}

/// Fixed-width table printer used by all figure harnesses.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Table { headers: headers.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:>w$}", c, w = widths[i]));
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// CSV form (for EXPERIMENTS.md extraction).
    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let mut x = 0u64;
        let m = bench(
            || {
                for i in 0..1000 {
                    x = x.wrapping_add(i);
                }
                std::hint::black_box(x);
            },
            2,
            5,
            10_000,
        );
        assert!(m.iters >= 3);
        assert!(m.median_ns > 0.0);
        assert!(m.mad_ns >= 0.0);
        assert!(m.mean_ns > 0.0);
    }

    #[test]
    fn table_render_and_csv() {
        let mut t = Table::new(vec!["k", "speedup"]);
        t.row(vec!["256", "1.20"]);
        t.row(vec!["4096", "2.44"]);
        let s = t.render();
        assert!(s.contains("speedup"));
        assert!(s.contains("2.44"));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("k,speedup"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }
}

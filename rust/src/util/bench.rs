//! Criterion-less benchmark harness (criterion is unavailable offline —
//! DESIGN.md §7): warmup + timed iterations, robust statistics, the
//! fixed-width table printer the figure harnesses share, and the
//! `BENCH_kernels.json` emitter that records the repo's measured perf
//! trajectory (EXPERIMENTS.md reads its "measured" column from it).

use std::time::Instant;

/// Result of one measurement: nanoseconds per iteration.
#[derive(Debug, Clone, Copy)]
pub struct Measurement {
    pub median_ns: f64,
    pub mean_ns: f64,
    /// median absolute deviation (robust spread)
    pub mad_ns: f64,
    pub iters: usize,
}

impl Measurement {
    pub fn micros(&self) -> f64 {
        self.median_ns / 1e3
    }

    pub fn millis(&self) -> f64 {
        self.median_ns / 1e6
    }
}

/// Benchmark a closure: `warmup` untimed runs, then time per-iteration
/// until `min_total_ms` of samples or `max_iters`, whichever first.
pub fn bench<F: FnMut()>(mut f: F, warmup: usize, min_total_ms: u64, max_iters: usize) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::new();
    let budget = std::time::Duration::from_millis(min_total_ms);
    let start = Instant::now();
    while (start.elapsed() < budget && samples.len() < max_iters) || samples.len() < 3 {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    summarize(&mut samples)
}

fn summarize(samples: &mut [f64]) -> Measurement {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Measurement {
        median_ns: median,
        mean_ns: mean,
        mad_ns: devs[devs.len() / 2],
        iters: samples.len(),
    }
}

/// Fixed-width table printer used by all figure harnesses.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Table { headers: headers.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:>w$}", c, w = widths[i]));
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// CSV form (for EXPERIMENTS.md extraction).
    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// One kernel measurement destined for `BENCH_kernels.json`: method ×
/// variant × shape → time.  `ns_per_elem` is the headline metric the
/// perf trajectory tracks (EXPERIMENTS.md).
#[derive(Debug, Clone)]
pub struct BenchRecord {
    /// registry kernel name (`fullpack-w4a8-swar`, ...)
    pub kernel: String,
    /// data variant the kernel ran (`w4a8`, ...)
    pub variant: String,
    /// output rows
    pub z: usize,
    /// logical depth
    pub k: usize,
    /// median wall-clock nanoseconds of one call
    pub median_ns: f64,
    /// timed iterations behind the median (0 = modeled, not measured)
    pub iters: usize,
}

impl BenchRecord {
    /// Nanoseconds per logical matrix element — the shape-normalized
    /// metric `BENCH_kernels.json` records.
    pub fn ns_per_elem(&self) -> f64 {
        self.median_ns / (self.z * self.k) as f64
    }
}

/// Escape a string for embedding in a JSON document (shared by every
/// BENCH_*.json emitter, including `workload::report`).
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render the `BENCH_kernels.json` document (schema `bench-kernels/v1`).
/// `source` says how the numbers were obtained (`"measured"` from a
/// bench run, `"costmodel-portable"` for modeled placeholders); `host`
/// and `note` are free-form provenance.
pub fn bench_records_json(source: &str, host: &str, note: &str, records: &[BenchRecord]) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": \"bench-kernels/v1\",\n");
    out.push_str(&format!("  \"source\": \"{}\",\n", json_escape(source)));
    out.push_str(&format!("  \"host\": \"{}\",\n", json_escape(host)));
    out.push_str(&format!("  \"note\": \"{}\",\n", json_escape(note)));
    out.push_str("  \"records\": [\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"variant\": \"{}\", \"z\": {}, \"k\": {}, \
             \"median_ns\": {:.1}, \"ns_per_elem\": {:.6}, \"iters\": {}}}{}\n",
            json_escape(&r.kernel),
            json_escape(&r.variant),
            r.z,
            r.k,
            r.median_ns,
            r.ns_per_elem(),
            r.iters,
            if i + 1 < records.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write [`bench_records_json`] to `path` (the repo convention is
/// `BENCH_kernels.json` at the repository root).
pub fn write_bench_json(
    path: &str,
    source: &str,
    host: &str,
    note: &str,
    records: &[BenchRecord],
) -> std::io::Result<()> {
    std::fs::write(path, bench_records_json(source, host, note, records))
}

/// One batched-GEMM measurement destined for `BENCH_gemm.json` (schema
/// `bench-gemm/v2`): backend × variant × shape × batch → time for
/// **one whole batched call**, plus the modeled per-level cache stats
/// of that call.  `ns_per_col` is the amortization metric the
/// crossover table tracks (EXPERIMENTS.md): per-column cost falling
/// with batch is the GEMM tier's whole argument; the cache columns are
/// the *memory* half of it (one weight pass vs `batch` re-streams —
/// `costmodel::simulate_gemm_traced`).
#[derive(Debug, Clone)]
pub struct GemmBenchRecord {
    /// registry GEMM backend name (`fullpack-w4a8-gemm`, ...), or a
    /// labeled protocol like `repeated:fullpack-w4a8`
    pub kernel: String,
    /// data variant the backend ran (`w4a8`, ...)
    pub variant: String,
    /// output rows
    pub z: usize,
    /// logical depth
    pub k: usize,
    /// batch columns per call
    pub batch: usize,
    /// median wall-clock nanoseconds of one batched call
    pub median_ns: f64,
    /// timed iterations behind the median (0 = modeled, not measured)
    pub iters: usize,
    /// modeled L1 accesses of one steady-state batched call (always
    /// model-side, even in measured records: the host has no portable
    /// cache counters — provenance lives in the document `note`)
    pub l1_accesses: u64,
    /// modeled L1 misses
    pub l1_misses: u64,
    /// modeled LLC accesses
    pub llc_accesses: u64,
    /// modeled LLC misses
    pub llc_misses: u64,
    /// modeled LLC misses attributed to the weight operand — flat in
    /// batch for the one-weight-pass GEMM tier, linear for re-streamed
    /// rivals
    pub weight_llc_misses: u64,
}

impl GemmBenchRecord {
    /// Nanoseconds per batch column — the amortization metric.
    pub fn ns_per_col(&self) -> f64 {
        self.median_ns / self.batch.max(1) as f64
    }
}

/// Render the `BENCH_gemm.json` document (schema `bench-gemm/v2`:
/// memory-aware — every record carries the modeled per-level cache
/// stats of its batched call).  Same provenance convention as
/// [`bench_records_json`].
pub fn gemm_records_json(
    source: &str,
    host: &str,
    note: &str,
    records: &[GemmBenchRecord],
) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": \"bench-gemm/v2\",\n");
    out.push_str(&format!("  \"source\": \"{}\",\n", json_escape(source)));
    out.push_str(&format!("  \"host\": \"{}\",\n", json_escape(host)));
    out.push_str(&format!("  \"note\": \"{}\",\n", json_escape(note)));
    out.push_str("  \"records\": [\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"variant\": \"{}\", \"z\": {}, \"k\": {}, \
             \"batch\": {}, \"median_ns\": {:.1}, \"ns_per_col\": {:.1}, \"iters\": {}, \
             \"l1_accesses\": {}, \"l1_misses\": {}, \"llc_accesses\": {}, \
             \"llc_misses\": {}, \"weight_llc_misses\": {}}}{}\n",
            json_escape(&r.kernel),
            json_escape(&r.variant),
            r.z,
            r.k,
            r.batch,
            r.median_ns,
            r.ns_per_col(),
            r.iters,
            r.l1_accesses,
            r.l1_misses,
            r.llc_accesses,
            r.llc_misses,
            r.weight_llc_misses,
            if i + 1 < records.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write [`gemm_records_json`] to `path` (the repo convention is
/// `BENCH_gemm.json` at the repository root).
pub fn write_gemm_bench_json(
    path: &str,
    source: &str,
    host: &str,
    note: &str,
    records: &[GemmBenchRecord],
) -> std::io::Result<()> {
    std::fs::write(path, gemm_records_json(source, host, note, records))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn bench_returns_sane_stats() {
        let mut x = 0u64;
        let m = bench(
            || {
                for i in 0..1000 {
                    x = x.wrapping_add(i);
                }
                std::hint::black_box(x);
            },
            2,
            5,
            10_000,
        );
        assert!(m.iters >= 3);
        assert!(m.median_ns > 0.0);
        assert!(m.mad_ns >= 0.0);
        assert!(m.mean_ns > 0.0);
    }

    #[test]
    fn table_render_and_csv() {
        let mut t = Table::new(vec!["k", "speedup"]);
        t.row(vec!["256", "1.20"]);
        t.row(vec!["4096", "2.44"]);
        let s = t.render();
        assert!(s.contains("speedup"));
        assert!(s.contains("2.44"));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("k,speedup"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn bench_json_roundtrips_through_the_parser() {
        let records = vec![
            BenchRecord {
                kernel: "fullpack-w4a8".into(),
                variant: "w4a8".into(),
                z: 2048,
                k: 2048,
                median_ns: 1.5e6,
                iters: 40,
            },
            BenchRecord {
                kernel: "fullpack-w4a8-swar".into(),
                variant: "w4a8".into(),
                z: 2048,
                k: 2048,
                median_ns: 7.5e5,
                iters: 80,
            },
        ];
        let text = bench_records_json("measured", "test-host", "a \"note\"", &records);
        let j = Json::parse(&text).expect("emitted JSON parses");
        assert_eq!(j.get("schema").unwrap().as_str(), Some("bench-kernels/v1"));
        assert_eq!(j.get("source").unwrap().as_str(), Some("measured"));
        assert_eq!(j.get("note").unwrap().as_str(), Some("a \"note\""));
        let recs = j.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[1].get("kernel").unwrap().as_str(), Some("fullpack-w4a8-swar"));
        assert_eq!(recs[0].get("z").unwrap().as_usize(), Some(2048));
        let npe = recs[0].get("ns_per_elem").unwrap().as_f64().unwrap();
        assert!((npe - 1.5e6 / (2048.0 * 2048.0)).abs() < 1e-6);
        // the headline ratio is recomputable from the records
        let r0 = recs[0].get("median_ns").unwrap().as_f64().unwrap();
        let r1 = recs[1].get("median_ns").unwrap().as_f64().unwrap();
        assert!((r0 / r1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn gemm_json_roundtrips_through_the_parser() {
        let records = vec![
            GemmBenchRecord {
                kernel: "fullpack-w4a8-gemm".into(),
                variant: "w4a8".into(),
                z: 1024,
                k: 2048,
                batch: 16,
                median_ns: 8.0e5,
                iters: 20,
                l1_accesses: 1_000_000,
                l1_misses: 40_000,
                llc_accesses: 40_000,
                llc_misses: 16_384,
                weight_llc_misses: 16_000,
            },
            GemmBenchRecord {
                kernel: "repeated:fullpack-w4a8".into(),
                variant: "w4a8".into(),
                z: 1024,
                k: 2048,
                batch: 16,
                median_ns: 1.6e6,
                iters: 20,
                l1_accesses: 1_100_000,
                l1_misses: 500_000,
                llc_accesses: 500_000,
                llc_misses: 262_144,
                weight_llc_misses: 256_000,
            },
        ];
        let text = gemm_records_json("measured", "test-host", "", &records);
        let j = Json::parse(&text).expect("emitted JSON parses");
        assert_eq!(j.get("schema").unwrap().as_str(), Some("bench-gemm/v2"));
        let recs = j.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].get("batch").unwrap().as_usize(), Some(16));
        let per_col = recs[0].get("ns_per_col").unwrap().as_f64().unwrap();
        assert!((per_col - 8.0e5 / 16.0).abs() < 0.5);
        // the crossover ratio is recomputable from the records
        let r0 = recs[0].get("median_ns").unwrap().as_f64().unwrap();
        let r1 = recs[1].get("median_ns").unwrap().as_f64().unwrap();
        assert!((r1 / r0 - 2.0).abs() < 1e-9);
        // v2: the memory half — one weight pass vs 16 re-streams — is
        // readable straight off the records
        let w0 = recs[0].get("weight_llc_misses").unwrap().as_usize().unwrap();
        let w1 = recs[1].get("weight_llc_misses").unwrap().as_usize().unwrap();
        assert_eq!(w1 / w0, 16);
        assert!(recs[0].get("l1_accesses").unwrap().as_usize().is_some());
        assert!(recs[0].get("llc_misses").unwrap().as_usize().is_some());
    }

    #[test]
    fn bench_json_writes_to_disk() {
        let path = std::env::temp_dir().join("fullpack_bench_test.json");
        let path = path.to_str().unwrap().to_string();
        let rec = vec![BenchRecord {
            kernel: "ruy-w8a8".into(),
            variant: "w8a8".into(),
            z: 16,
            k: 16,
            median_ns: 100.0,
            iters: 3,
        }];
        write_bench_json(&path, "measured", "h", "", &rec).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
        let _ = std::fs::remove_file(&path);
    }
}

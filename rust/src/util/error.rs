//! Minimal `anyhow` stand-in (the crate is unavailable offline —
//! DESIGN.md §7): a string-backed error with context chaining, the
//! `anyhow!` / `bail!` macros, and a defaulted `Result` alias.  The
//! surface mirrors the `anyhow` subset this repo uses so call sites
//! read identically.

use std::fmt;

/// A boxed, human-readable error.  Like `anyhow::Error` it deliberately
/// does **not** implement `std::error::Error`, which is what allows the
/// blanket `From<E: Error>` conversion below to coexist with the
/// reflexive `From<Error>`.
pub struct Error {
    msg: String,
    /// typed payload preserved by [`Error::new`] — the `anyhow`
    /// downcast surface, so typed refusals (e.g. the coordinator's
    /// `SubmitError`) survive the trip through the convenience wrappers
    source: Option<Box<dyn std::any::Any + Send + Sync>>,
}

impl Error {
    /// Build from anything displayable (the `anyhow!` macro's backend).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Build from a typed error, keeping the value recoverable with
    /// [`Error::downcast_ref`] (mirrors `anyhow::Error::new`).
    pub fn new<E>(e: E) -> Error
    where
        E: fmt::Display + Send + Sync + 'static,
    {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }

    /// The typed payload, if this error was built with [`Error::new`]
    /// from a `T` (mirrors `anyhow::Error::downcast_ref`).
    pub fn downcast_ref<T: 'static>(&self) -> Option<&T> {
        self.source.as_deref().and_then(|s| s.downcast_ref::<T>())
    }

    /// Prepend a context layer: `outer: inner`.  The typed payload, if
    /// any, stays downcastable underneath the new message.
    pub fn context(self, c: impl fmt::Display) -> Error {
        Error { msg: format!("{c}: {}", self.msg), source: self.source }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` defaulting to [`Error`], as `anyhow::Result` does.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string, a displayable value, or
/// a format string with arguments — the three `anyhow!` forms.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::util::error::Error::msg(format!($msg)) };
    ($err:expr $(,)?) => { $crate::util::error::Error::msg($err) };
    ($fmt:expr, $($arg:tt)*) => { $crate::util::error::Error::msg(format!($fmt, $($arg)*)) };
}

/// Early-return an `Err` built from a format string.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return Err($crate::anyhow!($($t)*).into()) };
}

pub use crate::{anyhow, bail};

/// Context-chaining on fallible values (`anyhow::Context` subset).
pub trait Context<T> {
    fn context(self, c: impl fmt::Display) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context(self, c: impl fmt::Display) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/no/such/file/at/all")
            .with_context(|| "reading config".to_string())?;
        Ok(s)
    }

    #[test]
    fn macro_and_context_chain() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        let chained = io_fail().unwrap_err().to_string();
        assert!(chained.starts_with("reading config: "), "{chained}");
    }

    #[test]
    fn bail_early_returns() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
    }

    #[test]
    fn converts_std_errors() {
        let r: Result<i32> = "xyz".parse::<i32>().map_err(Into::into);
        assert!(r.is_err());
    }
}

//! Small in-repo utilities replacing crates unavailable offline
//! (DESIGN.md §7): a JSON parser, a bench harness, an error type, and a
//! property-testing micro-framework.

pub mod bench;
pub mod error;
pub mod json;
pub mod proptest_lite;
pub mod rng;

//! Small in-repo utilities replacing crates unavailable offline
//! (DESIGN.md §7): a JSON parser, a bench harness, and a
//! property-testing micro-framework.

pub mod bench;
pub mod json;
pub mod proptest_lite;

//! Property-testing micro-framework (proptest is unavailable offline —
//! DESIGN.md §7): seeded SplitMix64 generators, N-case runners, and
//! greedy input shrinking on failure.
//!
//! Usage (`no_run`: doctest binaries can't locate libxla's libstdc++ at
//! runtime in this image; the same code runs in the unit tests below):
//! ```no_run
//! use fullpack::util::proptest_lite::{Gen, run_prop};
//! run_prop(100, |g| {
//!     let v = g.vec_i8_in(-8, 7, 0, 64);
//!     let doubled: Vec<i16> = v.iter().map(|&x| x as i16 * 2).collect();
//!     doubled.iter().zip(&v).all(|(&d, &x)| d == x as i16 * 2)
//! });
//! ```

use crate::util::rng::SplitMix64;

/// Seeded generator for property tests — a thin wrapper over the
/// canonical [`SplitMix64`] in `util::rng` (byte-identical sequences
/// to the pre-extraction inline implementation).
pub struct Gen {
    rng: SplitMix64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: SplitMix64::new(seed) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.int_in(lo, hi)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.usize_in(lo, hi)
    }

    pub fn i8_in(&mut self, lo: i8, hi: i8) -> i8 {
        self.int_in(lo as i64, hi as i64) as i8
    }

    pub fn f32_unit(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Random-length vector of i8 in `[lo, hi]`.
    pub fn vec_i8_in(&mut self, lo: i8, hi: i8, min_len: usize, max_len: usize) -> Vec<i8> {
        let n = self.usize_in(min_len, max_len);
        (0..n).map(|_| self.i8_in(lo, hi)).collect()
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_in(0, items.len() - 1)]
    }
}

/// Run `cases` random cases of `prop`; panic with the failing seed on
/// the first counterexample.  Deterministic across runs (fixed base
/// seed), so failures are reproducible by seed.
pub fn run_prop<F: FnMut(&mut Gen) -> bool>(cases: usize, mut prop: F) {
    for case in 0..cases {
        let seed = 0xFEED_0000 + case as u64;
        let mut g = Gen::new(seed);
        if !prop(&mut g) {
            panic!("property failed at case {case} (seed {seed:#x}); re-run with Gen::new({seed:#x})");
        }
    }
}

/// Shrinking helper for vector-shaped inputs: greedily tries removing
/// chunks, then zeroing elements, while `fails` keeps returning true.
/// Returns the minimized failing input.
pub fn shrink_vec<T: Copy + Default, F: FnMut(&[T]) -> bool>(input: &[T], mut fails: F) -> Vec<T> {
    let mut cur: Vec<T> = input.to_vec();
    debug_assert!(fails(&cur), "shrink_vec needs a failing input");
    // pass 1: remove halves/quarters/single elements
    let mut chunk = (cur.len() / 2).max(1);
    while chunk >= 1 {
        let mut i = 0;
        while i + chunk <= cur.len() {
            let mut cand = cur.clone();
            cand.drain(i..i + chunk);
            if !cand.is_empty() && fails(&cand) {
                cur = cand;
            } else {
                i += chunk;
            }
        }
        if chunk == 1 {
            break;
        }
        chunk /= 2;
    }
    // pass 2: zero out elements
    for i in 0..cur.len() {
        let mut cand = cur.clone();
        cand[i] = T::default();
        if fails(&cand) {
            cur = cand;
        }
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let a: Vec<u64> = { let mut g = Gen::new(42); (0..5).map(|_| g.next_u64()).collect() };
        let b: Vec<u64> = { let mut g = Gen::new(42); (0..5).map(|_| g.next_u64()).collect() };
        assert_eq!(a, b);
    }

    #[test]
    fn ranges_respected() {
        let mut g = Gen::new(7);
        for _ in 0..1000 {
            let v = g.i8_in(-8, 7);
            assert!((-8..=7).contains(&v));
            let u = g.usize_in(3, 5);
            assert!((3..=5).contains(&u));
            let f = g.f32_unit();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn run_prop_passes_trivial() {
        run_prop(50, |g| g.int_in(0, 10) <= 10);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn run_prop_reports_failure() {
        run_prop(50, |g| g.int_in(0, 10) < 5);
    }

    #[test]
    fn shrink_finds_minimal() {
        // failing predicate: contains an element > 100
        let input: Vec<i32> = (0..64).map(|i| if i == 37 { 120 } else { i }).collect();
        let small = shrink_vec(&input, |v| v.iter().any(|&x| x > 100));
        assert_eq!(small, vec![120]);
    }
}

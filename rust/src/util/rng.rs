//! Seeded deterministic RNG streams (DESIGN.md §7: no external crates).
//!
//! One place for every random sequence the repo draws, replacing the
//! ad-hoc seed-offset patterns that used to live in `models/`, the
//! kernel test helpers and the property-test runner:
//!
//! * [`SplitMix64`] — the canonical seeded generator, with a
//!   **stream-splitting** API: [`SplitMix64::stream`]`(seed, id)`
//!   derives statistically independent substreams from one experiment
//!   seed, so the workload-mix sampler (stream = mix index), every
//!   loadgen client (stream = client id) and the property-test runner
//!   each replay their own reproducible sequence without colliding.
//!   Same `(seed, id)` ⇒ same sequence, every run.
//! * [`XorShift64`] — the legacy weight-value stream
//!   (`seed·φ | 1` xorshift), extracted **verbatim** so synthetic
//!   packed weights stay bit-identical to every earlier PR
//!   (`models::xorshift_vals`, `kernels::testutil::rngvals` and the
//!   pack-layout tests all draw from it; pinned by golden tests below).
//!
//! Determinism scope: integer paths are bit-stable across platforms;
//! the floating-point helpers ([`SplitMix64::exp`], log-uniform
//! sampling built on them) are bit-stable per host/libm — the
//! workload harness' byte-identical-mix-files invariant is a per-host
//! guarantee (tested by `rust/tests/workload_harness.rs`).
#![warn(missing_docs)]

/// 2⁶⁴/φ — the Weyl increment SplitMix64 is built on (and the seed
/// multiplier of the legacy xorshift weight stream).
pub const GOLDEN_GAMMA: u64 = 0x9E3779B97F4A7C15;

/// Offset folded into stream ids so `stream(seed, 0)` differs from
/// `new(seed)` (stream 0 must not alias the root sequence).
const STREAM_SALT: u64 = 0x1F0A_5C3B_2E8D_4B6F;

/// SplitMix64 finalizer: a bijective 64-bit mix (Stafford variant 13).
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// SplitMix64 — tiny, high-quality, deterministic (Steele et al.,
/// "Fast Splittable Pseudorandom Number Generators").
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// The root stream of `seed`.
    pub fn new(seed: u64) -> SplitMix64 {
        SplitMix64 { state: seed }
    }

    /// Substream `id` of `seed`: the stream id is finalized through
    /// [`mix64`] (after a golden-ratio spread) and XORed into the
    /// seed, so adjacent ids (0, 1, 2, …) land in unrelated regions of
    /// the state space.  This is how one experiment seed fans out into
    /// per-mix / per-client sequences that never share a prefix.
    pub fn stream(seed: u64, id: u64) -> SplitMix64 {
        SplitMix64 { state: seed ^ mix64(id.wrapping_mul(GOLDEN_GAMMA).wrapping_add(STREAM_SALT)) }
    }

    /// A child stream seeded from this stream's own sequence (for
    /// nesting deeper than the two-level `stream` API).
    pub fn split(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64())
    }

    /// Next 64 uniform bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GOLDEN_GAMMA);
        mix64(self.state)
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo + 1) as u64;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform `usize` in `[lo, hi]` inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.int_in(lo as i64, hi as i64) as usize
    }

    /// Uniform in `[0, 1)` with 53 random bits.
    pub fn f64_unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)` (degenerates to `lo` when `hi <= lo`).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        if hi <= lo {
            lo
        } else {
            lo + (hi - lo) * self.f64_unit()
        }
    }

    /// Log-uniform in `[lo, hi)` — equal probability per decade; the
    /// natural prior for rate sweeps spanning orders of magnitude.
    pub fn f64_log_in(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(lo > 0.0);
        if hi <= lo {
            lo
        } else {
            lo * (hi / lo).powf(self.f64_unit())
        }
    }

    /// Exponential variate with the given mean (Poisson inter-arrival
    /// gaps): `-mean · ln(1 - U)`.
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = self.f64_unit(); // in [0, 1) so 1-u is in (0, 1]
        -mean * (1.0 - u).ln()
    }

    /// Index `i` with probability `weights[i] / Σ weights` (weights
    /// need not be normalized; non-positive entries are never picked
    /// unless every entry is non-positive, which falls back to 0).
    pub fn pick_weighted(&mut self, weights: &[f64]) -> usize {
        debug_assert!(!weights.is_empty());
        let total: f64 = weights.iter().map(|w| w.max(0.0)).sum();
        if total <= 0.0 {
            return 0;
        }
        let mut x = self.f64_unit() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w.max(0.0);
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// The legacy weight-value stream: xorshift64 seeded by a golden-ratio
/// multiply (`| 1` keeps the state nonzero).  Every synthetic weight
/// matrix in the repo is drawn from this exact sequence — it must
/// never change, or packed models stop being bit-identical to the
/// Python twins and every golden test breaks.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    s: u64,
}

impl XorShift64 {
    /// The stream the legacy call sites seeded: state `seed·φ | 1`.
    pub fn seeded(seed: u64) -> XorShift64 {
        XorShift64 { s: seed.wrapping_mul(GOLDEN_GAMMA) | 1 }
    }

    /// Next xorshift64 state (13/7/17 shifts — returned directly, as
    /// the legacy inline loops did).
    pub fn next_u64(&mut self) -> u64 {
        self.s ^= self.s << 13;
        self.s ^= self.s >> 7;
        self.s ^= self.s << 17;
        self.s
    }
}

/// `n` deterministic values uniform in `[lo, hi]` from the legacy
/// weight stream — the body every ad-hoc copy of this helper shared
/// (`models::xorshift_vals`, `kernels::testutil::rngvals`, pack-layout
/// tests).  Centralized here; the copies now delegate.
pub fn xorshift_range_vals(lo: i8, hi: i8, n: usize, seed: u64) -> Vec<i8> {
    let span = (hi as i16 - lo as i16 + 1) as u64;
    let mut g = XorShift64::seeded(seed);
    (0..n).map(|_| (lo as i16 + (g.next_u64() % span) as i16) as i8).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_golden_sequence() {
        // pinned against an independent Python mirror of SplitMix64
        let mut g = SplitMix64::new(42);
        assert_eq!(g.next_u64(), 0xbdd732262feb6e95);
        assert_eq!(g.next_u64(), 0x28efe333b266f103);
        assert_eq!(g.next_u64(), 0x47526757130f9f52);
        assert_eq!(g.next_u64(), 0x581ce1ff0e4ae394);
    }

    #[test]
    fn stream_golden_and_independent() {
        // pinned against the same Python mirror
        assert_eq!(SplitMix64::stream(7, 0).next_u64(), 0x1daaab91c1952ccd);
        assert_eq!(SplitMix64::stream(7, 1).next_u64(), 0xa924a3e4a6302a19);
        assert_eq!(SplitMix64::stream(7, 2).next_u64(), 0xef3cab57541c7aed);
        // stream 0 must not alias the root sequence
        assert_ne!(SplitMix64::stream(7, 0).next_u64(), SplitMix64::new(7).next_u64());
        // adjacent streams diverge immediately and stay apart
        let a: Vec<u64> = {
            let mut g = SplitMix64::stream(9, 4);
            (0..32).map(|_| g.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut g = SplitMix64::stream(9, 5);
            (0..32).map(|_| g.next_u64()).collect()
        };
        assert!(a.iter().zip(&b).all(|(x, y)| x != y));
    }

    #[test]
    fn xorshift_golden_matches_legacy_inline_loops() {
        // the exact values the ad-hoc copies produced before extraction
        // (Python-mirrored); w4 range then w8 range
        assert_eq!(xorshift_range_vals(-8, 7, 8, 7), vec![2, 7, -1, -1, -8, 7, -6, -3]);
        assert_eq!(xorshift_range_vals(-128, 127, 6, 100), vec![5, -114, -92, 62, 105, -8]);
    }

    #[test]
    fn xorshift_matches_reference_reimplementation() {
        // belt-and-braces: re-derive the legacy loop inline and compare
        // across seeds and ranges
        for seed in [0u64, 1, 7, 1234] {
            let (lo, hi) = (-8i8, 7i8);
            let span = (hi as i16 - lo as i16 + 1) as u64;
            let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
            let expect: Vec<i8> = (0..64)
                .map(|_| {
                    s ^= s << 13;
                    s ^= s >> 7;
                    s ^= s << 17;
                    (lo as i16 + (s % span) as i16) as i8
                })
                .collect();
            assert_eq!(xorshift_range_vals(lo, hi, 64, seed), expect, "seed {seed}");
        }
    }

    #[test]
    fn ranges_and_distributions_sane() {
        let mut g = SplitMix64::new(3);
        for _ in 0..2000 {
            let v = g.int_in(-3, 5);
            assert!((-3..=5).contains(&v));
            let u = g.f64_unit();
            assert!((0.0..1.0).contains(&u));
            let f = g.f64_in(2.0, 4.0);
            assert!((2.0..4.0).contains(&f));
            let l = g.f64_log_in(10.0, 1000.0);
            assert!((10.0..1000.0).contains(&l));
            let e = g.exp(5.0);
            assert!(e >= 0.0 && e.is_finite());
        }
        // degenerate ranges collapse to lo
        assert_eq!(g.f64_in(3.0, 3.0), 3.0);
        assert_eq!(g.f64_log_in(3.0, 3.0), 3.0);
    }

    #[test]
    fn exp_mean_converges() {
        let mut g = SplitMix64::new(11);
        let n = 20_000;
        let sum: f64 = (0..n).map(|_| g.exp(100.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 100.0).abs() < 5.0, "exp mean {mean}");
    }

    #[test]
    fn pick_weighted_respects_weights() {
        let mut g = SplitMix64::new(17);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..8000 {
            counts[g.pick_weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.5, "ratio {ratio}");
        // all-zero weights fall back to index 0 instead of panicking
        assert_eq!(g.pick_weighted(&[0.0, 0.0]), 0);
    }

    #[test]
    fn split_children_are_reproducible() {
        let mut a = SplitMix64::new(5);
        let mut c1 = a.split();
        let mut b = SplitMix64::new(5);
        let mut c2 = b.split();
        assert_eq!(
            (0..8).map(|_| c1.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| c2.next_u64()).collect::<Vec<_>>()
        );
    }
}

//! Hand-rolled CLI (clap is unavailable offline — DESIGN.md §7).
//!
//! ```text
//! fullpack simulate <fig4|fig5|fig6|fig7|fig8|fig10|fig12|fig13|gemm-batch|lut-crossover|isa-crossover|all> [--quick] [--csv DIR]
//! fullpack simulate model [--name <zoo-name|all>] [--variant V] [--size full|tiny]
//! fullpack simulate --show-config [--preset NAME]
//! fullpack bench <fig11|deepspeech> [--variant V] [--kernel NAME] [--ms N]
//! fullpack serve [--model ZOO] [--model-manifest F.json] [--variant V] [--kernel NAME]
//!                [--requests N] [--workers N] [--tiny]
//!                [--slo-ms N] [--max-batch N] [--max-queue N] [--fixed-deadline]
//!                [--resident-mb N] [--pin NAME] [--swap-manifest F.json]
//! fullpack workload gen-mixes [--space F.json] [--seed N] [--count N] [--out DIR]
//! fullpack workload run --mix F.json [--virtual] [--verify] [--out BENCH.json]
//! fullpack workload sweep [--space F.json] [--seed N] [--count N] [--live] [--out F.json]
//! fullpack models list
//! fullpack models show <zoo-name> [--variant V] [--size full|tiny]
//! fullpack models store <out-dir> [--variant V] [--size full|tiny]
//! fullpack models store --inspect F.fpck
//! fullpack kernels list
//! fullpack artifact run <name> [--dir artifacts]
//! fullpack artifact list [--dir artifacts]
//! ```

use std::collections::HashMap;

/// Parsed command line: positionals + `--key value` / `--flag` options.
#[derive(Debug, Default)]
pub struct Args {
    pub positionals: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Flags that never take a value.
    const FLAGS: [&'static str; 9] = [
        "quick",
        "show-config",
        "breakdown",
        "tiny",
        "help",
        "virtual",
        "live",
        "verify",
        "fixed-deadline",
    ];

    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args, String> {
        let mut a = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if Self::FLAGS.contains(&name) {
                    a.flags.push(name.to_string());
                } else {
                    let val = it
                        .next()
                        .ok_or_else(|| format!("--{name} expects a value"))?;
                    a.options.insert(name.to_string(), val);
                }
            } else {
                a.positionals.push(arg);
            }
        }
        Ok(a)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad number {v:?}")),
        }
    }

    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(String::as_str)
    }
}

pub const USAGE: &str = "\
fullpack — sub-byte quantized inference engine (FullPack reproduction)

USAGE:
  fullpack simulate <fig4|fig5|fig6|fig7|fig8|fig10|fig12|fig13|gemm-batch|
                     lut-crossover|isa-crossover|all>
                    [--quick] [--csv DIR]      regenerate a paper figure
                                               (gemm-batch: the GEMM tier's
                                               memory-aware batch sweep;
                                               isa-crossover: the AVX2/NEON
                                               tier vs staged/SWAR)
  fullpack simulate model [--name <zoo|all>] [--variant V] [--size full|tiny]
                                               whole-model method comparison over
                                               the model zoo (simulate_model)
  fullpack simulate --show-config [--preset P] print a cache preset
  fullpack bench fig11 [--ms N]                measured CNN-FC sweep (RPi substitution)
  fullpack bench deepspeech [--variant V] [--kernel NAME] [--breakdown] [--tiny]
                                               measured end-to-end DeepSpeech
  fullpack serve [--config F.json] [--model ZOO] [--model-manifest F.json]
                 [--variant V] [--kernel NAME] [--requests N] [--workers N] [--tiny]
                 [--slo-ms N] [--max-batch N] [--max-queue N] [--fixed-deadline]
                 [--resident-mb N] [--pin NAME] [--swap-manifest F.json]
                                               serving-engine demo (latency/throughput;
                                               --model picks a zoo graph, --model-manifest
                                               a runtime JSON layer graph; --slo-ms /
                                               --max-batch / --max-queue tune admission,
                                               --fixed-deadline disables the cost-model
                                               scheduler for the legacy batching policy;
                                               --resident-mb budgets the model store,
                                               --pin exempts a model from eviction,
                                               --swap-manifest hot-swaps mid-run)
  fullpack workload gen-mixes [--space F.json] [--seed N] [--count N] [--out DIR]
                                               sample N concrete workload mixes from
                                               a mix space (seeded: same seed ⇒
                                               byte-identical files)
  fullpack workload run --mix F.json [--virtual] [--verify] [--out BENCH.json]
                                               replay one mix (default: live engine;
                                               --virtual: deterministic virtual clock;
                                               --verify: bit-check replies vs an
                                               unbatched reference)
  fullpack workload sweep [--space F.json] [--seed N] [--count N] [--live]
                          [--out BENCH_serve.json]
                                               sample + run a mix sweep and emit the
                                               bench-serve/v3 document + fig-serve
                                               tables (default mode: virtual)
  fullpack models list                         print the model-zoo registry table
  fullpack models show <zoo-name> [--variant V] [--size full|tiny]
                                               print one graph's topology + plans
  fullpack models store <out-dir> [--variant V] [--size full|tiny]
                                               pack compiled zoo weights into FPCK
                                               images (the store's zero-copy load path)
  fullpack models store --inspect F.fpck       list one FPCK image's tensors
  fullpack kernels list                        print the kernel registry table
  fullpack artifact list [--dir D]             list AOT artifacts
  fullpack artifact run <name> [--dir D]       execute one artifact via PJRT
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn positionals_and_options() {
        let a = parse("simulate fig4 --quick --csv out");
        assert_eq!(a.pos(0), Some("simulate"));
        assert_eq!(a.pos(1), Some("fig4"));
        assert!(a.flag("quick"));
        assert_eq!(a.opt("csv"), Some("out"));
        assert_eq!(a.opt_or("preset", "gem5"), "gem5");
    }

    #[test]
    fn numbers() {
        let a = parse("serve --requests 64");
        assert_eq!(a.opt_usize("requests", 8).unwrap(), 64);
        assert_eq!(a.opt_usize("workers", 2).unwrap(), 2);
        let bad = parse("serve --requests xyz");
        assert!(bad.opt_usize("requests", 8).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(vec!["--csv".to_string()]).is_err());
    }
}

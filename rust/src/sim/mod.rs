//! Cache-hierarchy simulation — the gem5 stand-in (DESIGN.md §2).
//!
//! * [`cache`] — set-associative LRU multi-level hierarchy with the
//!   paper's Table 1 / Table 2 presets;
//! * [`trace`] — per-kernel memory-trace generators replayed against it.
//!
//! The cost model (`crate::costmodel`) combines these cache statistics
//! with per-method instruction counts into cycles/IPC — regenerating
//! Figs. 4–8 and 12–13.

pub mod cache;
pub mod trace;

pub use cache::{CacheConfig, CacheStats, Hierarchy};
pub use trace::{
    replay_gemm, replay_gemm_at, replay_gemm_lut, replay_gemm_lut_at, replay_gemm_restream,
    replay_gemm_restream_at, replay_gemv, replay_gemv_at, replay_gemv_lut, replay_gemv_lut_at,
    replay_gemv_lut_restream, replay_gemv_traced, replay_gemv_traced_at, GemmTraffic,
    GemvTraffic, OperandStats, ReplayStats,
};

/// Named hierarchy presets (CLI `--cache` flag and Fig. 7 sweep).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CachePreset {
    /// Table 1: 128KB L1 + 2MB L2 (default)
    Gem5Ex5Big,
    /// Table 1 with the optional 8MB L3
    Gem5Ex5BigL3,
    /// Fig. 7a: 1MB L2
    L21M,
    /// Fig. 7c: 8MB L2
    L28M,
    /// Fig. 7d: L1 only
    L1Only,
    /// Table 2: Raspberry Pi 4 (Cortex-A72)
    Rpi4,
}

impl CachePreset {
    pub fn build(self) -> Hierarchy {
        match self {
            CachePreset::Gem5Ex5Big => cache::gem5_ex5_big(),
            CachePreset::Gem5Ex5BigL3 => cache::gem5_ex5_big_l3(),
            CachePreset::L21M => cache::with_l2_size(1 << 20),
            CachePreset::L28M => cache::with_l2_size(8 << 20),
            CachePreset::L1Only => cache::l1_only(),
            CachePreset::Rpi4 => cache::rpi4_a72(),
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "gem5" | "gem5-ex5-big" | "default" => CachePreset::Gem5Ex5Big,
            "gem5-l3" | "l3" => CachePreset::Gem5Ex5BigL3,
            "l2-1m" => CachePreset::L21M,
            "l2-8m" => CachePreset::L28M,
            "l1-only" => CachePreset::L1Only,
            "rpi4" => CachePreset::Rpi4,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            CachePreset::Gem5Ex5Big => "gem5-ex5-big (2MB L2)",
            CachePreset::Gem5Ex5BigL3 => "gem5-ex5-big + 8MB L3",
            CachePreset::L21M => "1MB L2",
            CachePreset::L28M => "8MB L2",
            CachePreset::L1Only => "L1 only",
            CachePreset::Rpi4 => "RPi4 Cortex-A72 (1MB L2)",
        }
    }

    pub const ALL: [CachePreset; 6] = [
        CachePreset::Gem5Ex5Big,
        CachePreset::Gem5Ex5BigL3,
        CachePreset::L21M,
        CachePreset::L28M,
        CachePreset::L1Only,
        CachePreset::Rpi4,
    ];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_parse_roundtrip() {
        assert_eq!(CachePreset::parse("gem5"), Some(CachePreset::Gem5Ex5Big));
        assert_eq!(CachePreset::parse("rpi4"), Some(CachePreset::Rpi4));
        assert_eq!(CachePreset::parse("bogus"), None);
        for p in CachePreset::ALL {
            assert!(!p.name().is_empty());
            let _ = p.build();
        }
    }
}

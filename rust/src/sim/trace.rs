//! Memory-trace generators: replay the line-granular access stream of a
//! GEMV/GEMM kernel against a [`Hierarchy`].
//!
//! The generators reproduce the *access pattern* of each method exactly
//! — bytes per weight row, bytes of activations re-read per row, the
//! weight/activation interleave of the inner loop, and output writes —
//! which is what determines every cache metric the paper reports.
//! (Simulating at line granularity is exact for these streaming
//! kernels: within one 64-byte line the 16-byte vector loads cannot
//! miss twice.)
//!
//! Two call shapes exist (the paper's memory claims, §4.3, are exactly
//! the difference between them):
//!
//! * [`replay_gemv`] — one GEMV pass (the `batch` field models kernels
//!   like ULPPACK— whose *single call* processes several columns per
//!   weight pass);
//! * [`replay_gemm`] — one batched FullPack GEMM call
//!   ([`GemmTraffic`]): **one** pass over each weight row's lines with
//!   the whole n-column activation panel streamed per line progress
//!   (the extract-once/MAC-many loop of `kernels::gemm_fullpack`), vs
//!   [`replay_gemm_restream`] — the rival protocol that re-streams the
//!   weight matrix once per column (the paper's "route GEMM to Ruy"
//!   fallback and the repeated-GEMV baseline), each column's
//!   activations and outputs at *distinct* addresses.
//!
//! Every replay returns a [`ReplayStats`]: summed access latency plus
//! per-operand access/LLC-miss counts, so the one-weight-pass advantage
//! is directly observable (`rust/tests/sim_trace.rs`).

use super::cache::Hierarchy;

/// Disjoint base addresses (no false aliasing between operands).
pub const W_BASE: u64 = 0x1000_0000;
pub const A_BASE: u64 = 0x6000_0000;
pub const O_BASE: u64 = 0x7000_0000;

/// Byte-level traffic description of one GEMV call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemvTraffic {
    /// output rows
    pub z: usize,
    /// packed weight bytes per row
    pub w_bytes_per_row: usize,
    /// packed activation bytes (per batch column)
    pub a_bytes: usize,
    /// batch columns processed per weight pass (1 for GEMV; 8 for the
    /// paper's ULPPACK— which only has a batched GEMM kernel)
    pub batch: usize,
    /// bytes per output element (4 for i32/f32)
    pub out_elem_bytes: usize,
}

impl GemvTraffic {
    /// Total bytes read from the weight matrix (once per call).
    pub fn weight_bytes(&self) -> usize {
        self.z * self.w_bytes_per_row
    }

    /// Total activation bytes *touched* per call (re-read per row; the
    /// cache decides how many reach memory).
    pub fn act_bytes_touched(&self) -> usize {
        self.z * self.a_bytes * self.batch
    }
}

/// Byte-level traffic description of one **batched GEMM** call: `batch`
/// activation columns against one weight pass (the FullPack GEMM tier,
/// `kernels::gemm_fullpack`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmTraffic {
    /// output rows
    pub z: usize,
    /// packed weight bytes per row
    pub w_bytes_per_row: usize,
    /// packed activation bytes per column
    pub a_bytes: usize,
    /// activation panel columns fed by the single weight pass
    pub batch: usize,
    /// bytes per output element (4 for i32)
    pub out_elem_bytes: usize,
}

impl GemmTraffic {
    /// Lift a single-column GEMV description to a `batch`-column GEMM
    /// call over the same layer (`t.batch` columns per weight pass fold
    /// into the panel).
    pub fn from_gemv(t: &GemvTraffic, batch: usize) -> GemmTraffic {
        GemmTraffic {
            z: t.z,
            w_bytes_per_row: t.w_bytes_per_row,
            a_bytes: t.a_bytes,
            batch: batch.max(1) * t.batch.max(1),
            out_elem_bytes: t.out_elem_bytes,
        }
    }

    /// Total bytes read from the weight matrix (once per call).
    pub fn weight_bytes(&self) -> usize {
        self.z * self.w_bytes_per_row
    }

    /// Bytes of the whole activation panel (one copy; re-read per row).
    pub fn panel_bytes(&self) -> usize {
        self.batch * self.a_bytes
    }

    /// Bytes of the batch-major output tile.
    pub fn out_bytes(&self) -> usize {
        self.z * self.batch * self.out_elem_bytes
    }
}

/// Access/LLC-miss accounting for one operand of a replay.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OperandStats {
    /// line-granular accesses issued for this operand
    pub accesses: u64,
    /// how many of them missed the last-level cache
    pub llc_misses: u64,
}

/// What one replay did: summed access latency plus per-operand splits.
/// The operand split is what makes the paper's locality claims
/// testable — e.g. "GEMM does one weight pass" is
/// `weights.llc_misses` staying flat in batch.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReplayStats {
    /// summed access latency in cycles (the raw-latency view; the cost
    /// model combines the hierarchy's per-level stats with the core
    /// model instead)
    pub latency: u64,
    /// weight-matrix accesses
    pub weights: OperandStats,
    /// activation accesses
    pub acts: OperandStats,
    /// output-write accesses (first touch of each output line)
    pub outs: OperandStats,
}

impl ReplayStats {
    /// Total line-granular accesses across all operands.
    pub fn total_accesses(&self) -> u64 {
        self.weights.accesses + self.acts.accesses + self.outs.accesses
    }

    /// Total LLC misses across all operands.
    pub fn total_llc_misses(&self) -> u64 {
        self.weights.llc_misses + self.acts.llc_misses + self.outs.llc_misses
    }
}

/// One classified access: records the operand's access count and
/// whether the hierarchy's LLC missed on it.
fn probe(h: &mut Hierarchy, addr: u64, op: &mut OperandStats) -> u64 {
    let miss0 = h.llc_stats().misses;
    let lat = h.access(addr);
    op.accesses += 1;
    if h.llc_stats().misses > miss0 {
        op.llc_misses += 1;
    }
    lat
}

/// The shared GEMV inner loop: one weight pass per (row, column) with
/// the activation vector streamed alongside in proportion, plus
/// first-touch output-line writes.  `out_off` is the running byte
/// offset into the output buffer, carried across calls so re-streamed
/// protocols fill one contiguous batch-major buffer.
fn replay_gemv_into(
    h: &mut Hierarchy,
    t: &GemvTraffic,
    w_base: u64,
    a_base: u64,
    o_base: u64,
    out_off: &mut usize,
    s: &mut ReplayStats,
) {
    let line = h.line_size();
    let wlines = t.w_bytes_per_row.div_ceil(line);
    let alines = t.a_bytes.div_ceil(line);
    for r in 0..t.z {
        let wrow = w_base + (r * t.w_bytes_per_row) as u64;
        for b in 0..t.batch {
            let acol = a_base + (b * t.a_bytes) as u64;
            let mut ai = 0usize;
            for wl in 0..wlines {
                s.latency += probe(h, wrow + (wl * line) as u64, &mut s.weights);
                // stream matching share of the activation vector
                let target = ((wl + 1) * alines) / wlines;
                while ai < target {
                    s.latency += probe(h, acol + (ai * line) as u64, &mut s.acts);
                    ai += 1;
                }
            }
            // output write (one element per row per batch column): the
            // line is accessed on *first touch* — tested before the
            // offset advances, so a call whose whole output fits one
            // line still records it (the old crossing test fired one
            // line late and skipped the trailing partial line entirely)
            if *out_off % line < t.out_elem_bytes {
                s.latency += probe(h, o_base + (*out_off / line * line) as u64, &mut s.outs);
            }
            *out_off += t.out_elem_bytes;
        }
    }
}

/// Replay one GEMV through the hierarchy.  Returns the summed access
/// latency in cycles; [`replay_gemv_traced`] returns the per-operand
/// split as well.
///
/// Inner-loop interleave: the kernel walks a weight row sequentially and
/// streams the activation vector alongside it in proportion — weight
/// line, then however many activation lines correspond to the same
/// element progress (Alg. 2 lines 6–13: one 16-byte weight load then E
/// activation loads).
pub fn replay_gemv(h: &mut Hierarchy, t: &GemvTraffic) -> u64 {
    replay_gemv_at(h, t, W_BASE, A_BASE, O_BASE)
}

/// [`replay_gemv`] with explicit operand base addresses — multi-layer
/// models place each layer's weights at distinct addresses so residency
/// is modeled per layer.
pub fn replay_gemv_at(
    h: &mut Hierarchy,
    t: &GemvTraffic,
    w_base: u64,
    a_base: u64,
    o_base: u64,
) -> u64 {
    replay_gemv_traced_at(h, t, w_base, a_base, o_base).latency
}

/// [`replay_gemv`] returning the full per-operand [`ReplayStats`].
pub fn replay_gemv_traced(h: &mut Hierarchy, t: &GemvTraffic) -> ReplayStats {
    replay_gemv_traced_at(h, t, W_BASE, A_BASE, O_BASE)
}

/// [`replay_gemv_traced`] with explicit operand base addresses.
pub fn replay_gemv_traced_at(
    h: &mut Hierarchy,
    t: &GemvTraffic,
    w_base: u64,
    a_base: u64,
    o_base: u64,
) -> ReplayStats {
    let mut s = ReplayStats::default();
    let mut out_off = 0usize;
    replay_gemv_into(h, t, w_base, a_base, o_base, &mut out_off, &mut s);
    s
}

/// Replay one batched FullPack GEMM call: the blocked
/// extract-once/MAC-many loop of `kernels::gemm_fullpack`.
///
/// Per output row the packed weight lines are walked once per
/// [`crate::kernels::fullpack_gemm::COL_TILE`]-column tile — exactly
/// the kernel's loop, so for batch > `COL_TILE` the intra-row re-walks
/// appear in the L1 stream (they stay L1-resident: a packed row is at
/// most a few KB, so the **LLC** sees one weight pass regardless of
/// batch).  At each line progress the matching share of the tile's
/// activation columns is streamed (the panel lives at distinct
/// per-column addresses, `A_BASE + c · a_bytes`), and the row's output
/// tile — one element per column, batch-major (`out[c·z + r]`) — is
/// written with first-touch line accounting.  At batch 1 the access
/// stream is identical to [`replay_gemv`]'s (pinned by
/// `rust/tests/sim_trace.rs`).
pub fn replay_gemm(h: &mut Hierarchy, t: &GemmTraffic) -> ReplayStats {
    replay_gemm_at(h, t, W_BASE, A_BASE, O_BASE)
}

/// [`replay_gemm`] with explicit operand base addresses.
pub fn replay_gemm_at(
    h: &mut Hierarchy,
    t: &GemmTraffic,
    w_base: u64,
    a_base: u64,
    o_base: u64,
) -> ReplayStats {
    let ct = crate::kernels::fullpack_gemm::COL_TILE;
    let line = h.line_size();
    let wlines = t.w_bytes_per_row.div_ceil(line);
    let alines = t.a_bytes.div_ceil(line);
    let mut s = ReplayStats::default();
    if t.batch == 0 {
        return s;
    }
    for r in 0..t.z {
        let wrow = w_base + (r * t.w_bytes_per_row) as u64;
        let mut c0 = 0usize;
        while c0 < t.batch {
            let cols = (t.batch - c0).min(ct);
            // one weight walk per column tile (the kernel's loop); the
            // tile's columns advance in lockstep with it
            let mut ai = 0usize;
            for wl in 0..wlines {
                s.latency += probe(h, wrow + (wl * line) as u64, &mut s.weights);
                let target = ((wl + 1) * alines) / wlines;
                while ai < target {
                    for c in c0..c0 + cols {
                        let addr = a_base + (c * t.a_bytes + ai * line) as u64;
                        s.latency += probe(h, addr, &mut s.acts);
                    }
                    ai += 1;
                }
            }
            // the tile's output elements, batch-major layout
            for c in c0..c0 + cols {
                let off = (c * t.z + r) * t.out_elem_bytes;
                if off % line < t.out_elem_bytes {
                    s.latency += probe(h, o_base + (off / line * line) as u64, &mut s.outs);
                }
            }
            c0 += cols;
        }
    }
    s
}

/// The rival protocol: `replays` back-to-back GEMV passes over the
/// *same* weight matrix — the paper's "route GEMM to Ruy" fallback and
/// the repeated-GEMV baseline (`ruy-like-w8a8-gemm` executes exactly
/// this).  Each pass re-streams every weight line; pass `j`'s
/// activation column(s) live at `a_base + j · batch · a_bytes` and its
/// outputs continue through one contiguous batch-major buffer, so
/// distinct columns never alias to one vector (the accounting bug this
/// function replaces modeled every column at the same address,
/// overstating rival locality).
pub fn replay_gemm_restream(h: &mut Hierarchy, t: &GemvTraffic, replays: usize) -> ReplayStats {
    replay_gemm_restream_at(h, t, replays, W_BASE, A_BASE, O_BASE)
}

/// [`replay_gemm_restream`] with explicit operand base addresses.
pub fn replay_gemm_restream_at(
    h: &mut Hierarchy,
    t: &GemvTraffic,
    replays: usize,
    w_base: u64,
    a_base: u64,
    o_base: u64,
) -> ReplayStats {
    let mut s = ReplayStats::default();
    let mut out_off = 0usize;
    for j in 0..replays {
        let acol = a_base + (j * t.batch.max(1) * t.a_bytes) as u64;
        replay_gemv_into(h, t, w_base, acol, o_base, &mut out_off, &mut s);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cache::{gem5_ex5_big, with_l2_size};

    fn traffic(z: usize, k: usize, w_bpe_num: usize, w_bpe_den: usize) -> GemvTraffic {
        GemvTraffic {
            z,
            w_bytes_per_row: k * w_bpe_num / w_bpe_den,
            a_bytes: k,
            batch: 1,
            out_elem_bytes: 4,
        }
    }

    #[test]
    fn packed_weights_halve_llc_traffic() {
        // paper Fig. 6a: at sizes where neither fits the LLC, W4A8 does
        // ~50% of the baseline's LLC accesses.
        let z = 4096;
        let k = 4096;
        let mut h8 = gem5_ex5_big();
        replay_gemv(&mut h8, &traffic(z, k, 1, 1)); // w8a8: 1 B/elem
        let mut h4 = gem5_ex5_big();
        replay_gemv(&mut h4, &traffic(z, k, 1, 2)); // w4a8: 0.5 B/elem
        let r = h4.llc_stats().accesses as f64 / h8.llc_stats().accesses as f64;
        assert!((0.45..0.62).contains(&r), "LLC access ratio {r}");
    }

    #[test]
    fn fits_in_llc_kills_misses() {
        // paper §4.3.1: when the packed matrix fits the L2 but W8A8 does
        // not, misses drop by ~90%.
        let z = 2048;
        let k = 2048; // 4MB at 8-bit (spills 2MB L2), 2MB at 4-bit (fits)
        let mut h8 = gem5_ex5_big();
        let mut h4 = gem5_ex5_big();
        for _ in 0..3 {
            // repeated inference calls: steady-state residency
            replay_gemv(&mut h8, &traffic(z, k, 1, 1));
            replay_gemv(&mut h4, &traffic(z, k, 1, 2));
        }
        let m8 = h8.llc_stats();
        let m4 = h4.llc_stats();
        assert!(m8.miss_rate() > 0.9, "baseline thrash: {}", m8.miss_rate());
        let ratio = m4.misses as f64 / m8.misses as f64;
        assert!(ratio < 0.4, "packed misses ratio {ratio}");
    }

    #[test]
    fn bigger_llc_moves_the_boundary() {
        // paper Fig. 7: an 8MB L2 keeps the 2048x2048 W8A8 matrix resident.
        let z = 2048;
        let k = 2048;
        let mut h = with_l2_size(8 << 20);
        for _ in 0..3 {
            replay_gemv(&mut h, &traffic(z, k, 1, 1));
        }
        assert!(h.llc_stats().miss_rate() < 0.4);
    }

    #[test]
    fn batch_reuses_weights() {
        let z = 512;
        let k = 512;
        let mut g1 = gem5_ex5_big();
        let t1 = GemvTraffic { batch: 8, ..traffic(z, k, 1, 1) };
        replay_gemv(&mut g1, &t1);
        // 8-batch GEMM touches the same weight lines once per row pass;
        // total L1 accesses grow with batch but weight misses don't 8x.
        let mut g0 = gem5_ex5_big();
        replay_gemv(&mut g0, &traffic(z, k, 1, 1));
        let m1 = g1.llc_stats().misses as f64;
        let m0 = g0.llc_stats().misses as f64;
        assert!(m1 < m0 * 3.0, "batched misses {m1} vs single {m0}");
    }

    #[test]
    fn traffic_helpers() {
        let t = traffic(4, 128, 1, 2);
        assert_eq!(t.weight_bytes(), 4 * 64);
        assert_eq!(t.act_bytes_touched(), 4 * 128);
        let g = GemmTraffic::from_gemv(&t, 8);
        assert_eq!(g.batch, 8);
        assert_eq!(g.weight_bytes(), t.weight_bytes());
        assert_eq!(g.panel_bytes(), 8 * 128);
        assert_eq!(g.out_bytes(), 8 * 4 * 4);
        // a traffic with an internal batch (ULPPACK) folds it in
        let u = GemvTraffic { batch: 8, ..t };
        assert_eq!(GemmTraffic::from_gemv(&u, 2).batch, 16);
    }

    #[test]
    fn small_outputs_are_accounted() {
        // regression (PR 4): z·batch·4 < 64 used to record ZERO output
        // traffic because the old crossing test only fired when the
        // running offset left a line
        let mut h = gem5_ex5_big();
        let s = replay_gemv_traced(&mut h, &traffic(4, 64, 1, 1)); // 16 out bytes
        assert_eq!(s.outs.accesses, 1, "one output line touched");
        // trailing partial line: 33 rows * 4 B = 132 B -> 3 lines
        let mut h = gem5_ex5_big();
        let s = replay_gemv_traced(&mut h, &traffic(33, 64, 1, 1));
        assert_eq!(s.outs.accesses, 3, "trailing partial output line");
    }

    #[test]
    fn gemm_one_weight_pass_vs_restream() {
        // the whole point of the tier: at a size where weights spill the
        // LLC, the batched call's weight misses stay at one pass while
        // the re-streamed rival pays them once per column
        let z = 4096;
        let k = 4096;
        let t = traffic(z, k, 1, 2); // w4a8-style packed rows
        let batch = 4;
        let mut hg = gem5_ex5_big();
        let g = replay_gemm(&mut hg, &GemmTraffic::from_gemv(&t, batch));
        let mut hr = gem5_ex5_big();
        let r = replay_gemm_restream(&mut hr, &t, batch);
        assert!(
            g.weights.llc_misses * 2 < r.weights.llc_misses,
            "gemm weight misses {} vs restream {}",
            g.weights.llc_misses,
            r.weights.llc_misses
        );
        // same logical work: identical access counts per operand
        // (batch == COL_TILE here, so the batched call is one tile and
        // walks each weight row exactly once)
        assert_eq!(g.weights.accesses * batch as u64, r.weights.accesses);
        assert_eq!(g.acts.accesses, r.acts.accesses);
        assert_eq!(g.outs.accesses, r.outs.accesses);
    }

    #[test]
    fn restream_columns_are_distinct() {
        // column j reads a_base + j*a_bytes: activation accesses (and
        // first-touch misses) must grow with the number of columns
        let t = traffic(64, 2048, 1, 1);
        let mut h1 = gem5_ex5_big();
        let s1 = replay_gemm_restream(&mut h1, &t, 1);
        let mut h8 = gem5_ex5_big();
        let s8 = replay_gemm_restream(&mut h8, &t, 8);
        assert_eq!(s8.acts.accesses, 8 * s1.acts.accesses);
        assert!(
            s8.acts.llc_misses >= 8 * s1.acts.llc_misses,
            "distinct columns must cold-miss independently: {} vs {}",
            s8.acts.llc_misses,
            s1.acts.llc_misses
        );
    }
}

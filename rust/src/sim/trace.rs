//! Memory-trace generators: replay the line-granular access stream of a
//! GEMV/GEMM kernel against a [`Hierarchy`].
//!
//! The generators reproduce the *access pattern* of each method exactly
//! — bytes per weight row, bytes of activations re-read per row, the
//! weight/activation interleave of the inner loop, and output writes —
//! which is what determines every cache metric the paper reports.
//! (Simulating at line granularity is exact for these streaming
//! kernels: within one 64-byte line the 16-byte vector loads cannot
//! miss twice.)

use super::cache::Hierarchy;

/// Disjoint base addresses (no false aliasing between operands).
pub const W_BASE: u64 = 0x1000_0000;
pub const A_BASE: u64 = 0x6000_0000;
pub const O_BASE: u64 = 0x7000_0000;

/// Byte-level traffic description of one GEMV call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemvTraffic {
    /// output rows
    pub z: usize,
    /// packed weight bytes per row
    pub w_bytes_per_row: usize,
    /// packed activation bytes (per batch column)
    pub a_bytes: usize,
    /// batch columns processed per weight pass (1 for GEMV; 8 for the
    /// paper's ULPPACK— which only has a batched GEMM kernel)
    pub batch: usize,
    /// bytes per output element (4 for i32/f32)
    pub out_elem_bytes: usize,
}

impl GemvTraffic {
    /// Total bytes read from the weight matrix (once per call).
    pub fn weight_bytes(&self) -> usize {
        self.z * self.w_bytes_per_row
    }

    /// Total activation bytes *touched* per call (re-read per row; the
    /// cache decides how many reach memory).
    pub fn act_bytes_touched(&self) -> usize {
        self.z * self.a_bytes * self.batch
    }
}

/// Replay one GEMV through the hierarchy.  Returns the summed access
/// latency in cycles (the raw-latency view; the cost model combines the
/// per-level stats with the core model instead).
///
/// Inner-loop interleave: the kernel walks a weight row sequentially and
/// streams the activation vector alongside it in proportion — weight
/// line, then however many activation lines correspond to the same
/// element progress (Alg. 2 lines 6–13: one 16-byte weight load then E
/// activation loads).
pub fn replay_gemv(h: &mut Hierarchy, t: &GemvTraffic) -> u64 {
    replay_gemv_at(h, t, W_BASE, A_BASE, O_BASE)
}

/// [`replay_gemv`] with explicit operand base addresses — multi-layer
/// models place each layer's weights at distinct addresses so residency
/// is modeled per layer.
pub fn replay_gemv_at(
    h: &mut Hierarchy,
    t: &GemvTraffic,
    w_base: u64,
    a_base: u64,
    o_base: u64,
) -> u64 {
    let line = h.line_size();
    let wlines = t.w_bytes_per_row.div_ceil(line);
    let alines = t.a_bytes.div_ceil(line);
    let mut latency = 0u64;
    let mut out_bytes = 0usize;
    for r in 0..t.z {
        let wrow = w_base + (r * t.w_bytes_per_row) as u64;
        for b in 0..t.batch {
            let acol = a_base + (b * t.a_bytes) as u64;
            let mut ai = 0usize;
            for wl in 0..wlines {
                latency += h.access(wrow + (wl * line) as u64);
                // stream matching share of the activation vector
                let target = ((wl + 1) * alines) / wlines;
                while ai < target {
                    latency += h.access(acol + (ai * line) as u64);
                    ai += 1;
                }
            }
            // output write (one element per row per batch column)
            out_bytes += t.out_elem_bytes;
            if out_bytes % line < t.out_elem_bytes {
                latency += h.access(o_base + (out_bytes - 1) as u64 / line as u64 * line as u64);
            }
        }
    }
    latency
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cache::{gem5_ex5_big, with_l2_size};

    fn traffic(z: usize, k: usize, w_bpe_num: usize, w_bpe_den: usize) -> GemvTraffic {
        GemvTraffic {
            z,
            w_bytes_per_row: k * w_bpe_num / w_bpe_den,
            a_bytes: k,
            batch: 1,
            out_elem_bytes: 4,
        }
    }

    #[test]
    fn packed_weights_halve_llc_traffic() {
        // paper Fig. 6a: at sizes where neither fits the LLC, W4A8 does
        // ~50% of the baseline's LLC accesses.
        let z = 4096;
        let k = 4096;
        let mut h8 = gem5_ex5_big();
        replay_gemv(&mut h8, &traffic(z, k, 1, 1)); // w8a8: 1 B/elem
        let mut h4 = gem5_ex5_big();
        replay_gemv(&mut h4, &traffic(z, k, 1, 2)); // w4a8: 0.5 B/elem
        let r = h4.llc_stats().accesses as f64 / h8.llc_stats().accesses as f64;
        assert!((0.45..0.62).contains(&r), "LLC access ratio {r}");
    }

    #[test]
    fn fits_in_llc_kills_misses() {
        // paper §4.3.1: when the packed matrix fits the L2 but W8A8 does
        // not, misses drop by ~90%.
        let z = 2048;
        let k = 2048; // 4MB at 8-bit (spills 2MB L2), 2MB at 4-bit (fits)
        let mut h8 = gem5_ex5_big();
        let mut h4 = gem5_ex5_big();
        for _ in 0..3 {
            // repeated inference calls: steady-state residency
            replay_gemv(&mut h8, &traffic(z, k, 1, 1));
            replay_gemv(&mut h4, &traffic(z, k, 1, 2));
        }
        let m8 = h8.llc_stats();
        let m4 = h4.llc_stats();
        assert!(m8.miss_rate() > 0.9, "baseline thrash: {}", m8.miss_rate());
        let ratio = m4.misses as f64 / m8.misses as f64;
        assert!(ratio < 0.4, "packed misses ratio {ratio}");
    }

    #[test]
    fn bigger_llc_moves_the_boundary() {
        // paper Fig. 7: an 8MB L2 keeps the 2048x2048 W8A8 matrix resident.
        let z = 2048;
        let k = 2048;
        let mut h = with_l2_size(8 << 20);
        for _ in 0..3 {
            replay_gemv(&mut h, &traffic(z, k, 1, 1));
        }
        assert!(h.llc_stats().miss_rate() < 0.4);
    }

    #[test]
    fn batch_reuses_weights() {
        let z = 512;
        let k = 512;
        let mut g1 = gem5_ex5_big();
        let t1 = GemvTraffic { batch: 8, ..traffic(z, k, 1, 1) };
        replay_gemv(&mut g1, &t1);
        // 8-batch GEMM touches the same weight lines once per row pass;
        // total L1 accesses grow with batch but weight misses don't 8x.
        let mut g0 = gem5_ex5_big();
        replay_gemv(&mut g0, &traffic(z, k, 1, 1));
        let m1 = g1.llc_stats().misses as f64;
        let m0 = g0.llc_stats().misses as f64;
        assert!(m1 < m0 * 3.0, "batched misses {m1} vs single {m0}");
    }

    #[test]
    fn traffic_helpers() {
        let t = traffic(4, 128, 1, 2);
        assert_eq!(t.weight_bytes(), 4 * 64);
        assert_eq!(t.act_bytes_touched(), 4 * 128);
    }
}

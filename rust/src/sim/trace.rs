//! Memory-trace generators: replay the line-granular access stream of a
//! GEMV/GEMM kernel against a [`Hierarchy`].
//!
//! The generators reproduce the *access pattern* of each method exactly
//! — bytes per weight row, bytes of activations re-read per row, the
//! weight/activation interleave of the inner loop, and output writes —
//! which is what determines every cache metric the paper reports.
//! (Simulating at line granularity is exact for these streaming
//! kernels: within one 64-byte line the 16-byte vector loads cannot
//! miss twice.)
//!
//! Two call shapes exist (the paper's memory claims, §4.3, are exactly
//! the difference between them):
//!
//! * [`replay_gemv`] — one GEMV pass (the `batch` field models kernels
//!   like ULPPACK— whose *single call* processes several columns per
//!   weight pass);
//! * [`replay_gemm`] — one batched FullPack GEMM call
//!   ([`GemmTraffic`]): **one** pass over each weight row's lines with
//!   the whole n-column activation panel streamed per line progress
//!   (the extract-once/MAC-many loop of `kernels::gemm_fullpack`), vs
//!   [`replay_gemm_restream`] — the rival protocol that re-streams the
//!   weight matrix once per column (the paper's "route GEMM to Ruy"
//!   fallback and the repeated-GEMV baseline), each column's
//!   activations and outputs at *distinct* addresses.
//!
//! Every replay returns a [`ReplayStats`]: summed access latency plus
//! per-operand access/LLC-miss counts, so the one-weight-pass advantage
//! is directly observable (`rust/tests/sim_trace.rs`).

use super::cache::Hierarchy;

/// Disjoint base addresses (no false aliasing between operands).
pub const W_BASE: u64 = 0x1000_0000;
pub const A_BASE: u64 = 0x6000_0000;
pub const O_BASE: u64 = 0x7000_0000;
/// Base of the LUT tier's per-call table scratch ([`replay_gemv_lut`]).
pub const T_BASE: u64 = 0x9000_0000;

/// Byte-level traffic description of one GEMV call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemvTraffic {
    /// output rows
    pub z: usize,
    /// packed weight bytes per row
    pub w_bytes_per_row: usize,
    /// packed activation bytes (per batch column)
    pub a_bytes: usize,
    /// batch columns processed per weight pass (1 for GEMV; 8 for the
    /// paper's ULPPACK— which only has a batched GEMM kernel)
    pub batch: usize,
    /// bytes per output element (4 for i32/f32)
    pub out_elem_bytes: usize,
}

impl GemvTraffic {
    /// Total bytes read from the weight matrix (once per call).
    pub fn weight_bytes(&self) -> usize {
        self.z * self.w_bytes_per_row
    }

    /// Total activation bytes *touched* per call (re-read per row; the
    /// cache decides how many reach memory).
    pub fn act_bytes_touched(&self) -> usize {
        self.z * self.a_bytes * self.batch
    }
}

/// Byte-level traffic description of one **batched GEMM** call: `batch`
/// activation columns against one weight pass (the FullPack GEMM tier,
/// `kernels::gemm_fullpack`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmTraffic {
    /// output rows
    pub z: usize,
    /// packed weight bytes per row
    pub w_bytes_per_row: usize,
    /// packed activation bytes per column
    pub a_bytes: usize,
    /// activation panel columns fed by the single weight pass
    pub batch: usize,
    /// bytes per output element (4 for i32)
    pub out_elem_bytes: usize,
}

impl GemmTraffic {
    /// Lift a single-column GEMV description to a `batch`-column GEMM
    /// call over the same layer (`t.batch` columns per weight pass fold
    /// into the panel).
    pub fn from_gemv(t: &GemvTraffic, batch: usize) -> GemmTraffic {
        GemmTraffic {
            z: t.z,
            w_bytes_per_row: t.w_bytes_per_row,
            a_bytes: t.a_bytes,
            batch: batch.max(1) * t.batch.max(1),
            out_elem_bytes: t.out_elem_bytes,
        }
    }

    /// Total bytes read from the weight matrix (once per call).
    pub fn weight_bytes(&self) -> usize {
        self.z * self.w_bytes_per_row
    }

    /// Bytes of the whole activation panel (one copy; re-read per row).
    pub fn panel_bytes(&self) -> usize {
        self.batch * self.a_bytes
    }

    /// Bytes of the batch-major output tile.
    pub fn out_bytes(&self) -> usize {
        self.z * self.batch * self.out_elem_bytes
    }
}

/// Access/LLC-miss accounting for one operand of a replay.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OperandStats {
    /// line-granular accesses issued for this operand
    pub accesses: u64,
    /// how many of them missed the last-level cache
    pub llc_misses: u64,
}

/// What one replay did: summed access latency plus per-operand splits.
/// The operand split is what makes the paper's locality claims
/// testable — e.g. "GEMM does one weight pass" is
/// `weights.llc_misses` staying flat in batch.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReplayStats {
    /// summed access latency in cycles (the raw-latency view; the cost
    /// model combines the hierarchy's per-level stats with the core
    /// model instead)
    pub latency: u64,
    /// weight-matrix accesses
    pub weights: OperandStats,
    /// activation accesses
    pub acts: OperandStats,
    /// output-write accesses (first touch of each output line)
    pub outs: OperandStats,
}

impl ReplayStats {
    /// Total line-granular accesses across all operands.
    pub fn total_accesses(&self) -> u64 {
        self.weights.accesses + self.acts.accesses + self.outs.accesses
    }

    /// Total LLC misses across all operands.
    pub fn total_llc_misses(&self) -> u64 {
        self.weights.llc_misses + self.acts.llc_misses + self.outs.llc_misses
    }
}

/// One classified access: records the operand's access count and
/// whether the hierarchy's LLC missed on it.
fn probe(h: &mut Hierarchy, addr: u64, op: &mut OperandStats) -> u64 {
    let miss0 = h.llc_stats().misses;
    let lat = h.access(addr);
    op.accesses += 1;
    if h.llc_stats().misses > miss0 {
        op.llc_misses += 1;
    }
    lat
}

/// The shared GEMV inner loop: one weight pass per (row, column) with
/// the activation vector streamed alongside in proportion, plus
/// first-touch output-line writes.  `out_off` is the running byte
/// offset into the output buffer, carried across calls so re-streamed
/// protocols fill one contiguous batch-major buffer.
fn replay_gemv_into(
    h: &mut Hierarchy,
    t: &GemvTraffic,
    w_base: u64,
    a_base: u64,
    o_base: u64,
    out_off: &mut usize,
    s: &mut ReplayStats,
) {
    let line = h.line_size();
    let wlines = t.w_bytes_per_row.div_ceil(line);
    let alines = t.a_bytes.div_ceil(line);
    for r in 0..t.z {
        let wrow = w_base + (r * t.w_bytes_per_row) as u64;
        for b in 0..t.batch {
            let acol = a_base + (b * t.a_bytes) as u64;
            let mut ai = 0usize;
            for wl in 0..wlines {
                s.latency += probe(h, wrow + (wl * line) as u64, &mut s.weights);
                // stream matching share of the activation vector
                let target = ((wl + 1) * alines) / wlines;
                while ai < target {
                    s.latency += probe(h, acol + (ai * line) as u64, &mut s.acts);
                    ai += 1;
                }
            }
            // output write (one element per row per batch column): the
            // line is accessed on *first touch* — tested before the
            // offset advances, so a call whose whole output fits one
            // line still records it (the old crossing test fired one
            // line late and skipped the trailing partial line entirely)
            if *out_off % line < t.out_elem_bytes {
                s.latency += probe(h, o_base + (*out_off / line * line) as u64, &mut s.outs);
            }
            *out_off += t.out_elem_bytes;
        }
    }
}

/// Replay one GEMV through the hierarchy.  Returns the summed access
/// latency in cycles; [`replay_gemv_traced`] returns the per-operand
/// split as well.
///
/// Inner-loop interleave: the kernel walks a weight row sequentially and
/// streams the activation vector alongside it in proportion — weight
/// line, then however many activation lines correspond to the same
/// element progress (Alg. 2 lines 6–13: one 16-byte weight load then E
/// activation loads).
pub fn replay_gemv(h: &mut Hierarchy, t: &GemvTraffic) -> u64 {
    replay_gemv_at(h, t, W_BASE, A_BASE, O_BASE)
}

/// [`replay_gemv`] with explicit operand base addresses — multi-layer
/// models place each layer's weights at distinct addresses so residency
/// is modeled per layer.
pub fn replay_gemv_at(
    h: &mut Hierarchy,
    t: &GemvTraffic,
    w_base: u64,
    a_base: u64,
    o_base: u64,
) -> u64 {
    replay_gemv_traced_at(h, t, w_base, a_base, o_base).latency
}

/// [`replay_gemv`] returning the full per-operand [`ReplayStats`].
pub fn replay_gemv_traced(h: &mut Hierarchy, t: &GemvTraffic) -> ReplayStats {
    replay_gemv_traced_at(h, t, W_BASE, A_BASE, O_BASE)
}

/// [`replay_gemv_traced`] with explicit operand base addresses.
pub fn replay_gemv_traced_at(
    h: &mut Hierarchy,
    t: &GemvTraffic,
    w_base: u64,
    a_base: u64,
    o_base: u64,
) -> ReplayStats {
    let mut s = ReplayStats::default();
    let mut out_off = 0usize;
    replay_gemv_into(h, t, w_base, a_base, o_base, &mut out_off, &mut s);
    s
}

/// Replay one batched FullPack GEMM call: the blocked
/// extract-once/MAC-many loop of `kernels::gemm_fullpack`.
///
/// Per output row the packed weight lines are walked once per
/// [`crate::kernels::fullpack_gemm::COL_TILE`]-column tile — exactly
/// the kernel's loop, so for batch > `COL_TILE` the intra-row re-walks
/// appear in the L1 stream (they stay L1-resident: a packed row is at
/// most a few KB, so the **LLC** sees one weight pass regardless of
/// batch).  At each line progress the matching share of the tile's
/// activation columns is streamed (the panel lives at distinct
/// per-column addresses, `A_BASE + c · a_bytes`), and the row's output
/// tile — one element per column, batch-major (`out[c·z + r]`) — is
/// written with first-touch line accounting.  At batch 1 the access
/// stream is identical to [`replay_gemv`]'s (pinned by
/// `rust/tests/sim_trace.rs`).
pub fn replay_gemm(h: &mut Hierarchy, t: &GemmTraffic) -> ReplayStats {
    replay_gemm_at(h, t, W_BASE, A_BASE, O_BASE)
}

/// [`replay_gemm`] with explicit operand base addresses.
pub fn replay_gemm_at(
    h: &mut Hierarchy,
    t: &GemmTraffic,
    w_base: u64,
    a_base: u64,
    o_base: u64,
) -> ReplayStats {
    let ct = crate::kernels::fullpack_gemm::COL_TILE;
    let line = h.line_size();
    let wlines = t.w_bytes_per_row.div_ceil(line);
    let alines = t.a_bytes.div_ceil(line);
    let mut s = ReplayStats::default();
    if t.batch == 0 {
        return s;
    }
    for r in 0..t.z {
        let wrow = w_base + (r * t.w_bytes_per_row) as u64;
        let mut c0 = 0usize;
        while c0 < t.batch {
            let cols = (t.batch - c0).min(ct);
            // one weight walk per column tile (the kernel's loop); the
            // tile's columns advance in lockstep with it
            let mut ai = 0usize;
            for wl in 0..wlines {
                s.latency += probe(h, wrow + (wl * line) as u64, &mut s.weights);
                let target = ((wl + 1) * alines) / wlines;
                while ai < target {
                    for c in c0..c0 + cols {
                        let addr = a_base + (c * t.a_bytes + ai * line) as u64;
                        s.latency += probe(h, addr, &mut s.acts);
                    }
                    ai += 1;
                }
            }
            // the tile's output elements, batch-major layout
            for c in c0..c0 + cols {
                let off = (c * t.z + r) * t.out_elem_bytes;
                if off % line < t.out_elem_bytes {
                    s.latency += probe(h, o_base + (off / line * line) as u64, &mut s.outs);
                }
            }
            c0 += cols;
        }
    }
    s
}

/// The rival protocol: `replays` back-to-back GEMV passes over the
/// *same* weight matrix — the paper's "route GEMM to Ruy" fallback and
/// the repeated-GEMV baseline (`ruy-like-w8a8-gemm` executes exactly
/// this).  Each pass re-streams every weight line; pass `j`'s
/// activation column(s) live at `a_base + j · batch · a_bytes` and its
/// outputs continue through one contiguous batch-major buffer, so
/// distinct columns never alias to one vector (the accounting bug this
/// function replaces modeled every column at the same address,
/// overstating rival locality).
pub fn replay_gemm_restream(h: &mut Hierarchy, t: &GemvTraffic, replays: usize) -> ReplayStats {
    replay_gemm_restream_at(h, t, replays, W_BASE, A_BASE, O_BASE)
}

/// [`replay_gemm_restream`] with explicit operand base addresses.
pub fn replay_gemm_restream_at(
    h: &mut Hierarchy,
    t: &GemvTraffic,
    replays: usize,
    w_base: u64,
    a_base: u64,
    o_base: u64,
) -> ReplayStats {
    let mut s = ReplayStats::default();
    let mut out_off = 0usize;
    for j in 0..replays {
        let acol = a_base + (j * t.batch.max(1) * t.a_bytes) as u64;
        replay_gemv_into(h, t, w_base, acol, o_base, &mut out_off, &mut s);
    }
    s
}

/// Bytes of LUT-tier scratch per packed weight byte slot: a 256-entry
/// i32 table of partial dots, one entry per possible byte value
/// (`kernels::lut`).
pub const LUT_SLOT_BYTES: usize = 256 * 4;

/// Build one column's LUT scratch: stream the column's packed
/// activations once, then touch every scratch line (the incremental
/// recurrence fills each 256-entry slot sequentially).  Table traffic
/// is folded into the **`acts` operand** — the table *is* derived
/// activation state (its contents change whenever the activations do),
/// and keeping [`ReplayStats`] at three operands preserves every
/// existing consumer of the split.
fn lut_build_table(
    h: &mut Hierarchy,
    a_col: u64,
    t_col: u64,
    a_bytes: usize,
    wb: usize,
    s: &mut ReplayStats,
) {
    let line = h.line_size();
    for al in 0..a_bytes.div_ceil(line) {
        s.latency += probe(h, a_col + (al * line) as u64, &mut s.acts);
    }
    for tl in 0..(wb * LUT_SLOT_BYTES).div_ceil(line) {
        s.latency += probe(h, t_col + (tl * line) as u64, &mut s.acts);
    }
}

/// The shared LUT GEMV loop: per-column tables built up front, then one
/// pass over the packed weight rows with one gather-style table access
/// per weight byte per column.  The gathered line within a slot is
/// picked by a deterministic hash of `(row, byte position)` — the real
/// index is the weight byte's *value*, which is uniform enough that any
/// fixed spread models the same locality (what matters is that
/// consecutive gathers land in *different* slots, `LUT_SLOT_BYTES`
/// apart, so the table's L1 footprint is its whole `wb · 1 KB`).
fn replay_gemv_lut_into(
    h: &mut Hierarchy,
    t: &GemvTraffic,
    w_base: u64,
    a_base: u64,
    o_base: u64,
    out_off: &mut usize,
    s: &mut ReplayStats,
) {
    let line = h.line_size();
    let wb = t.w_bytes_per_row;
    let wlines = wb.div_ceil(line);
    let batch = t.batch.max(1);
    let table_bytes = wb * LUT_SLOT_BYTES;
    for c in 0..batch {
        let t_col = T_BASE + (c * table_bytes) as u64;
        lut_build_table(h, a_base + (c * t.a_bytes) as u64, t_col, t.a_bytes, wb, s);
    }
    for r in 0..t.z {
        let wrow = w_base + (r * wb) as u64;
        for wl in 0..wlines {
            s.latency += probe(h, wrow + (wl * line) as u64, &mut s.weights);
            // one gather per packed byte in this line, per column: the
            // slot is picked by the byte's position, the line within
            // the slot by the byte's data-dependent value
            for pos in wl * line..((wl + 1) * line).min(wb) {
                let val = (r * 67 + pos * 31) % 256;
                for c in 0..batch {
                    let addr =
                        T_BASE + (c * table_bytes + pos * LUT_SLOT_BYTES + val * 4) as u64;
                    s.latency += probe(h, addr, &mut s.acts);
                }
            }
        }
        for _ in 0..batch {
            if *out_off % line < t.out_elem_bytes {
                s.latency += probe(h, o_base + (*out_off / line * line) as u64, &mut s.outs);
            }
            *out_off += t.out_elem_bytes;
        }
    }
}

/// Replay one LUT-tier GEMV call (`kernels::lut`, `Method::Lut`): the
/// per-call table build — every scratch line written once, charged to
/// the `acts` operand — followed by **one** pass over the packed weight
/// rows where each weight byte costs one gather into the table at
/// [`T_BASE`].  The weight stream is identical to [`replay_gemv`]'s;
/// the difference is the table: `w_bytes_per_row · 1 KB` of hot scratch
/// that competes with everything else for L1 — the
/// L1-pressure-vs-bandwidth trade the tier embodies.
pub fn replay_gemv_lut(h: &mut Hierarchy, t: &GemvTraffic) -> ReplayStats {
    replay_gemv_lut_at(h, t, W_BASE, A_BASE, O_BASE)
}

/// [`replay_gemv_lut`] with explicit operand base addresses (the table
/// scratch stays at [`T_BASE`] — it is per-call scratch, not an
/// operand).
pub fn replay_gemv_lut_at(
    h: &mut Hierarchy,
    t: &GemvTraffic,
    w_base: u64,
    a_base: u64,
    o_base: u64,
) -> ReplayStats {
    let mut s = ReplayStats::default();
    let mut out_off = 0usize;
    replay_gemv_lut_into(h, t, w_base, a_base, o_base, &mut out_off, &mut s);
    s
}

/// The LUT tier's repeated-GEMV rival protocol: `replays` back-to-back
/// [`replay_gemv_lut`] calls over the same weights, column `j`'s
/// activations at distinct addresses, each call **rebuilding** the
/// table into the same scratch (the per-call cost the `lut-*-gemm`
/// wrappers cannot amortize — only the weight stream is tile-shared).
pub fn replay_gemv_lut_restream(
    h: &mut Hierarchy,
    t: &GemvTraffic,
    replays: usize,
) -> ReplayStats {
    let mut s = ReplayStats::default();
    let mut out_off = 0usize;
    for j in 0..replays {
        let acol = A_BASE + (j * t.batch.max(1) * t.a_bytes) as u64;
        replay_gemv_lut_into(h, t, W_BASE, acol, O_BASE, &mut out_off, &mut s);
    }
    s
}

/// Replay one batched LUT GEMM call (`kernels::lut`, `Method::LutGemm`):
/// per [`crate::kernels::fullpack_gemm::COL_TILE`]-column tile, the
/// tile's tables are built once (into scratch reused across tiles),
/// then **one** weight pass feeds every column of the tile — so weight
/// accesses grow as `⌈batch/COL_TILE⌉`, not `batch`, while table
/// builds and gathers stay strictly per column.  At batch 1 the access
/// stream is identical to [`replay_gemv_lut`]'s (pinned below).
pub fn replay_gemm_lut(h: &mut Hierarchy, t: &GemmTraffic) -> ReplayStats {
    replay_gemm_lut_at(h, t, W_BASE, A_BASE, O_BASE)
}

/// [`replay_gemm_lut`] with explicit operand base addresses.
pub fn replay_gemm_lut_at(
    h: &mut Hierarchy,
    t: &GemmTraffic,
    w_base: u64,
    a_base: u64,
    o_base: u64,
) -> ReplayStats {
    let ct = crate::kernels::fullpack_gemm::COL_TILE;
    let line = h.line_size();
    let wb = t.w_bytes_per_row;
    let wlines = wb.div_ceil(line);
    let table_bytes = wb * LUT_SLOT_BYTES;
    let mut s = ReplayStats::default();
    if t.batch == 0 {
        return s;
    }
    let mut c0 = 0usize;
    while c0 < t.batch {
        let cols = (t.batch - c0).min(ct);
        for ci in 0..cols {
            lut_build_table(
                h,
                a_base + ((c0 + ci) * t.a_bytes) as u64,
                T_BASE + (ci * table_bytes) as u64,
                t.a_bytes,
                wb,
                &mut s,
            );
        }
        for r in 0..t.z {
            let wrow = w_base + (r * wb) as u64;
            for wl in 0..wlines {
                s.latency += probe(h, wrow + (wl * line) as u64, &mut s.weights);
                for pos in wl * line..((wl + 1) * line).min(wb) {
                    let val = (r * 67 + pos * 31) % 256;
                    for ci in 0..cols {
                        let addr =
                            T_BASE + (ci * table_bytes + pos * LUT_SLOT_BYTES + val * 4) as u64;
                        s.latency += probe(h, addr, &mut s.acts);
                    }
                }
            }
            // the tile's output elements, batch-major layout
            for ci in 0..cols {
                let off = ((c0 + ci) * t.z + r) * t.out_elem_bytes;
                if off % line < t.out_elem_bytes {
                    s.latency += probe(h, o_base + (off / line * line) as u64, &mut s.outs);
                }
            }
        }
        c0 += cols;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cache::{gem5_ex5_big, with_l2_size};

    fn traffic(z: usize, k: usize, w_bpe_num: usize, w_bpe_den: usize) -> GemvTraffic {
        GemvTraffic {
            z,
            w_bytes_per_row: k * w_bpe_num / w_bpe_den,
            a_bytes: k,
            batch: 1,
            out_elem_bytes: 4,
        }
    }

    #[test]
    fn packed_weights_halve_llc_traffic() {
        // paper Fig. 6a: at sizes where neither fits the LLC, W4A8 does
        // ~50% of the baseline's LLC accesses.
        let z = 4096;
        let k = 4096;
        let mut h8 = gem5_ex5_big();
        replay_gemv(&mut h8, &traffic(z, k, 1, 1)); // w8a8: 1 B/elem
        let mut h4 = gem5_ex5_big();
        replay_gemv(&mut h4, &traffic(z, k, 1, 2)); // w4a8: 0.5 B/elem
        let r = h4.llc_stats().accesses as f64 / h8.llc_stats().accesses as f64;
        assert!((0.45..0.62).contains(&r), "LLC access ratio {r}");
    }

    #[test]
    fn fits_in_llc_kills_misses() {
        // paper §4.3.1: when the packed matrix fits the L2 but W8A8 does
        // not, misses drop by ~90%.
        let z = 2048;
        let k = 2048; // 4MB at 8-bit (spills 2MB L2), 2MB at 4-bit (fits)
        let mut h8 = gem5_ex5_big();
        let mut h4 = gem5_ex5_big();
        for _ in 0..3 {
            // repeated inference calls: steady-state residency
            replay_gemv(&mut h8, &traffic(z, k, 1, 1));
            replay_gemv(&mut h4, &traffic(z, k, 1, 2));
        }
        let m8 = h8.llc_stats();
        let m4 = h4.llc_stats();
        assert!(m8.miss_rate() > 0.9, "baseline thrash: {}", m8.miss_rate());
        let ratio = m4.misses as f64 / m8.misses as f64;
        assert!(ratio < 0.4, "packed misses ratio {ratio}");
    }

    #[test]
    fn bigger_llc_moves_the_boundary() {
        // paper Fig. 7: an 8MB L2 keeps the 2048x2048 W8A8 matrix resident.
        let z = 2048;
        let k = 2048;
        let mut h = with_l2_size(8 << 20);
        for _ in 0..3 {
            replay_gemv(&mut h, &traffic(z, k, 1, 1));
        }
        assert!(h.llc_stats().miss_rate() < 0.4);
    }

    #[test]
    fn batch_reuses_weights() {
        let z = 512;
        let k = 512;
        let mut g1 = gem5_ex5_big();
        let t1 = GemvTraffic { batch: 8, ..traffic(z, k, 1, 1) };
        replay_gemv(&mut g1, &t1);
        // 8-batch GEMM touches the same weight lines once per row pass;
        // total L1 accesses grow with batch but weight misses don't 8x.
        let mut g0 = gem5_ex5_big();
        replay_gemv(&mut g0, &traffic(z, k, 1, 1));
        let m1 = g1.llc_stats().misses as f64;
        let m0 = g0.llc_stats().misses as f64;
        assert!(m1 < m0 * 3.0, "batched misses {m1} vs single {m0}");
    }

    #[test]
    fn traffic_helpers() {
        let t = traffic(4, 128, 1, 2);
        assert_eq!(t.weight_bytes(), 4 * 64);
        assert_eq!(t.act_bytes_touched(), 4 * 128);
        let g = GemmTraffic::from_gemv(&t, 8);
        assert_eq!(g.batch, 8);
        assert_eq!(g.weight_bytes(), t.weight_bytes());
        assert_eq!(g.panel_bytes(), 8 * 128);
        assert_eq!(g.out_bytes(), 8 * 4 * 4);
        // a traffic with an internal batch (ULPPACK) folds it in
        let u = GemvTraffic { batch: 8, ..t };
        assert_eq!(GemmTraffic::from_gemv(&u, 2).batch, 16);
    }

    #[test]
    fn small_outputs_are_accounted() {
        // regression (PR 4): z·batch·4 < 64 used to record ZERO output
        // traffic because the old crossing test only fired when the
        // running offset left a line
        let mut h = gem5_ex5_big();
        let s = replay_gemv_traced(&mut h, &traffic(4, 64, 1, 1)); // 16 out bytes
        assert_eq!(s.outs.accesses, 1, "one output line touched");
        // trailing partial line: 33 rows * 4 B = 132 B -> 3 lines
        let mut h = gem5_ex5_big();
        let s = replay_gemv_traced(&mut h, &traffic(33, 64, 1, 1));
        assert_eq!(s.outs.accesses, 3, "trailing partial output line");
    }

    #[test]
    fn gemm_one_weight_pass_vs_restream() {
        // the whole point of the tier: at a size where weights spill the
        // LLC, the batched call's weight misses stay at one pass while
        // the re-streamed rival pays them once per column
        let z = 4096;
        let k = 4096;
        let t = traffic(z, k, 1, 2); // w4a8-style packed rows
        let batch = 4;
        let mut hg = gem5_ex5_big();
        let g = replay_gemm(&mut hg, &GemmTraffic::from_gemv(&t, batch));
        let mut hr = gem5_ex5_big();
        let r = replay_gemm_restream(&mut hr, &t, batch);
        assert!(
            g.weights.llc_misses * 2 < r.weights.llc_misses,
            "gemm weight misses {} vs restream {}",
            g.weights.llc_misses,
            r.weights.llc_misses
        );
        // same logical work: identical access counts per operand
        // (batch == COL_TILE here, so the batched call is one tile and
        // walks each weight row exactly once)
        assert_eq!(g.weights.accesses * batch as u64, r.weights.accesses);
        assert_eq!(g.acts.accesses, r.acts.accesses);
        assert_eq!(g.outs.accesses, r.outs.accesses);
    }

    #[test]
    fn restream_columns_are_distinct() {
        // column j reads a_base + j*a_bytes: activation accesses (and
        // first-touch misses) must grow with the number of columns
        let t = traffic(64, 2048, 1, 1);
        let mut h1 = gem5_ex5_big();
        let s1 = replay_gemm_restream(&mut h1, &t, 1);
        let mut h8 = gem5_ex5_big();
        let s8 = replay_gemm_restream(&mut h8, &t, 8);
        assert_eq!(s8.acts.accesses, 8 * s1.acts.accesses);
        assert!(
            s8.acts.llc_misses >= 8 * s1.acts.llc_misses,
            "distinct columns must cold-miss independently: {} vs {}",
            s8.acts.llc_misses,
            s1.acts.llc_misses
        );
    }

    #[test]
    fn lut_gemv_walks_weights_once_and_builds_table() {
        let t = traffic(256, 2048, 1, 2); // w4a8-style: wb = 1024
        let mut h = gem5_ex5_big();
        let s = replay_gemv_lut(&mut h, &t);
        // the weight stream is exactly replay_gemv's: one pass
        let wlines = t.w_bytes_per_row.div_ceil(64);
        assert_eq!(s.weights.accesses, (t.z * wlines) as u64, "one weight pass");
        // acts = the activation stream + every scratch line written
        // once (build) + one gather per weight byte per row
        let table_lines = t.w_bytes_per_row * LUT_SLOT_BYTES / 64;
        let alines = t.a_bytes.div_ceil(64);
        let gathers = t.z * t.w_bytes_per_row;
        assert_eq!(s.acts.accesses, (alines + table_lines + gathers) as u64);
    }

    #[test]
    fn lut_table_pressure_visible_in_l1() {
        // wb=64: 64KB of scratch fits the 128KB L1 — gathers mostly
        // hit.  wb=1024: 1MB of scratch thrashes L1 (while still
        // fitting the 2MB L2) — gathers miss L1 nearly every time.
        // This is the tier's modeled trade: table L1 pressure bought
        // with the same packed-weight bandwidth as FullPack.
        let small = traffic(512, 128, 1, 2);
        let big = traffic(512, 2048, 1, 2);
        let mut hs = gem5_ex5_big();
        replay_gemv_lut(&mut hs, &small);
        let mut hb = gem5_ex5_big();
        replay_gemv_lut(&mut hb, &big);
        let (ms, mb) = (hs.level_stats(0).miss_rate(), hb.level_stats(0).miss_rate());
        assert!(mb > 2.0 * ms, "L1 thrash when the table outgrows it: {ms} vs {mb}");
    }

    #[test]
    fn lut_gemm_batch1_equals_gemv_and_amortizes_weight_stream() {
        let t = traffic(128, 1024, 1, 2);
        let mut hg = gem5_ex5_big();
        let g1 = replay_gemm_lut(&mut hg, &GemmTraffic::from_gemv(&t, 1));
        let mut hv = gem5_ex5_big();
        let v = replay_gemv_lut(&mut hv, &t);
        assert_eq!(g1, v, "batch 1 degenerates to the GEMV replay");
        // batch 8 is two COL_TILE=4 tiles: weight accesses double
        // rather than 8x, while the rival restream pays the full 8x
        let mut h8 = gem5_ex5_big();
        let g8 = replay_gemm_lut(&mut h8, &GemmTraffic::from_gemv(&t, 8));
        assert_eq!(g8.weights.accesses, 2 * v.weights.accesses);
        let mut hr = gem5_ex5_big();
        let r8 = replay_gemv_lut_restream(&mut hr, &t, 8);
        assert_eq!(r8.weights.accesses, 8 * v.weights.accesses);
        // per-column table work (builds + gathers) and output traffic
        // are identical under both protocols — only the weight stream
        // amortizes
        assert_eq!(g8.acts.accesses, r8.acts.accesses);
        assert_eq!(g8.outs.accesses, r8.outs.accesses);
    }
}

//! Set-associative LRU cache-hierarchy simulator — the stand-in for the
//! paper's gem5 memory system (DESIGN.md substitution table).  Every
//! LLC metric in Figs. 6 and 7 (accesses, misses, miss rate, miss
//! latency) is read off this model.

/// Configuration of one cache level.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheConfig {
    pub name: &'static str,
    /// total capacity in bytes
    pub size: usize,
    /// line size in bytes
    pub line: usize,
    /// associativity (ways per set)
    pub assoc: usize,
    /// latency of a hit in this level, in cycles
    pub hit_latency: u64,
}

impl CacheConfig {
    pub fn sets(&self) -> usize {
        (self.size / self.line / self.assoc).max(1)
    }
}

/// Running statistics for one level.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub accesses: u64,
    pub misses: u64,
    /// total cycles spent below this level on its misses
    pub miss_latency_total: u64,
}

impl CacheStats {
    pub fn miss_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses as f64
        }
    }

    /// average additional latency per miss
    pub fn avg_miss_latency(&self) -> f64 {
        if self.misses == 0 {
            0.0
        } else {
            self.miss_latency_total as f64 / self.misses as f64
        }
    }
}

/// One set-associative LRU cache level.  Tags are stored per set in MRU
/// order (index 0 = most recent).
struct CacheLevel {
    cfg: CacheConfig,
    sets: Vec<Vec<u64>>,
    stats: CacheStats,
    set_mask: u64,
    line_shift: u32,
}

impl CacheLevel {
    fn new(cfg: CacheConfig) -> Self {
        let sets = cfg.sets();
        assert!(sets.is_power_of_two(), "{}: set count must be a power of two", cfg.name);
        assert!(cfg.line.is_power_of_two());
        CacheLevel {
            set_mask: (sets - 1) as u64,
            line_shift: cfg.line.trailing_zeros(),
            sets: vec![Vec::with_capacity(cfg.assoc); sets],
            cfg,
            stats: CacheStats::default(),
        }
    }

    /// Access a line address; returns true on hit.  Misses fill (LRU
    /// eviction).
    fn access(&mut self, addr: u64) -> bool {
        let tag = addr >> self.line_shift;
        let set = &mut self.sets[(tag & self.set_mask) as usize];
        self.stats.accesses += 1;
        if let Some(pos) = set.iter().position(|&t| t == tag) {
            // MRU update
            let t = set.remove(pos);
            set.insert(0, t);
            true
        } else {
            self.stats.misses += 1;
            if set.len() == self.cfg.assoc {
                set.pop();
            }
            set.insert(0, tag);
            false
        }
    }
}

/// A multi-level hierarchy with a flat DRAM behind the last level.
pub struct Hierarchy {
    levels: Vec<CacheLevel>,
    /// DRAM access latency in cycles
    pub mem_latency: u64,
}

impl Hierarchy {
    pub fn new(configs: Vec<CacheConfig>, mem_latency: u64) -> Self {
        assert!(!configs.is_empty(), "need at least one cache level");
        Hierarchy {
            levels: configs.into_iter().map(CacheLevel::new).collect(),
            mem_latency,
        }
    }

    /// Number of levels (the last one is the LLC).
    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    pub fn line_size(&self) -> usize {
        self.levels[0].cfg.line
    }

    /// Simulate one line-granular access; returns its total latency in
    /// cycles.  Each level is probed in order; on a miss the next level
    /// is consulted; DRAM always hits.
    pub fn access(&mut self, addr: u64) -> u64 {
        let mut latency = 0;
        let n = self.levels.len();
        for i in 0..n {
            latency += self.levels[i].cfg.hit_latency;
            if self.levels[i].access(addr) {
                return latency;
            }
        }
        latency += self.mem_latency;
        // attribute the below-LLC latency to the LLC's miss accounting
        let llc = self.levels.last_mut().unwrap();
        llc.stats.miss_latency_total += self.mem_latency;
        latency
    }

    /// Stats of level `i` (0 = L1).
    pub fn level_stats(&self, i: usize) -> CacheStats {
        self.levels[i].stats
    }

    /// Stats of the last-level cache — the paper's Fig. 6 metrics.
    pub fn llc_stats(&self) -> CacheStats {
        self.levels.last().unwrap().stats
    }

    pub fn level_config(&self, i: usize) -> &CacheConfig {
        &self.levels[i].cfg
    }

    pub fn reset_stats(&mut self) {
        for l in &mut self.levels {
            l.stats = CacheStats::default();
        }
    }
}

/// gem5 Table 1 configuration: modified ex5_big, 128KB L1D, 2MB L2
/// (LLC), LPDDR3-class memory.
pub fn gem5_ex5_big() -> Hierarchy {
    Hierarchy::new(
        vec![
            CacheConfig { name: "L1D", size: 128 << 10, line: 64, assoc: 2, hit_latency: 2 },
            CacheConfig { name: "L2", size: 2 << 20, line: 64, assoc: 16, hit_latency: 12 },
        ],
        140,
    )
}

/// Table 1 variant with the optional 8MB L3 ("where employed").
pub fn gem5_ex5_big_l3() -> Hierarchy {
    Hierarchy::new(
        vec![
            CacheConfig { name: "L1D", size: 128 << 10, line: 64, assoc: 2, hit_latency: 2 },
            CacheConfig { name: "L2", size: 2 << 20, line: 64, assoc: 16, hit_latency: 12 },
            CacheConfig { name: "L3", size: 8 << 20, line: 64, assoc: 16, hit_latency: 30 },
        ],
        140,
    )
}

/// Custom L2 size (Fig. 7 sweep), keeping the Table 1 L1.
pub fn with_l2_size(l2_bytes: usize) -> Hierarchy {
    Hierarchy::new(
        vec![
            CacheConfig { name: "L1D", size: 128 << 10, line: 64, assoc: 2, hit_latency: 2 },
            CacheConfig { name: "L2", size: l2_bytes, line: 64, assoc: 16, hit_latency: 12 },
        ],
        140,
    )
}

/// L1-only hierarchy (Fig. 7d: "L2 and L3 removed").
pub fn l1_only() -> Hierarchy {
    Hierarchy::new(
        vec![CacheConfig { name: "L1D", size: 128 << 10, line: 64, assoc: 2, hit_latency: 2 }],
        140,
    )
}

/// Raspberry Pi 4 (Table 2): Cortex-A72, 32KB L1D, 1MB shared L2.
pub fn rpi4_a72() -> Hierarchy {
    Hierarchy::new(
        vec![
            CacheConfig { name: "L1D", size: 32 << 10, line: 64, assoc: 2, hit_latency: 2 },
            CacheConfig { name: "L2", size: 1 << 20, line: 64, assoc: 16, hit_latency: 15 },
        ],
        160,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Hierarchy {
        Hierarchy::new(
            vec![
                CacheConfig { name: "L1", size: 256, line: 64, assoc: 2, hit_latency: 1 },
                CacheConfig { name: "L2", size: 1024, line: 64, assoc: 2, hit_latency: 10 },
            ],
            100,
        )
    }

    #[test]
    fn first_access_misses_everywhere() {
        let mut h = tiny();
        let lat = h.access(0);
        assert_eq!(lat, 1 + 10 + 100);
        assert_eq!(h.level_stats(0).misses, 1);
        assert_eq!(h.llc_stats().misses, 1);
        assert_eq!(h.llc_stats().miss_latency_total, 100);
    }

    #[test]
    fn second_access_hits_l1() {
        let mut h = tiny();
        h.access(0);
        let lat = h.access(0);
        assert_eq!(lat, 1);
        assert_eq!(h.level_stats(0).accesses, 2);
        assert_eq!(h.level_stats(0).misses, 1);
        // L2 only saw the first (missing) access
        assert_eq!(h.llc_stats().accesses, 1);
    }

    #[test]
    fn same_line_is_one_entry() {
        let mut h = tiny();
        h.access(0);
        assert_eq!(h.access(63), 1); // same 64B line
        assert_eq!(h.access(64), 1 + 10 + 100); // next line
    }

    #[test]
    fn lru_eviction_order() {
        // L1: 256B/64B/2-way = 2 sets; addresses mapping to set 0:
        // lines 0, 2, 4 (line index mod 2 == 0)
        let mut h = tiny();
        h.access(0); // line 0 -> set 0
        h.access(128); // line 2 -> set 0
        h.access(256); // line 4 -> set 0, evicts line 0 (LRU)
        assert_eq!(h.level_stats(0).misses, 3);
        h.access(128); // still resident (MRU before line 4 arrived)
        assert_eq!(h.level_stats(0).misses, 3);
        h.access(0); // was evicted -> L1 miss (but L2 hit)
        assert_eq!(h.level_stats(0).misses, 4);
        assert_eq!(h.llc_stats().misses, 3); // L2 held it
    }

    #[test]
    fn misses_bounded_by_accesses() {
        let mut h = tiny();
        let mut s: u64 = 9;
        for _ in 0..10_000 {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            h.access(s % 65536);
        }
        for lvl in 0..h.depth() {
            let st = h.level_stats(lvl);
            assert!(st.misses <= st.accesses);
        }
        let llc = h.llc_stats();
        assert!(llc.miss_rate() > 0.0 && llc.miss_rate() <= 1.0);
        assert!(llc.avg_miss_latency() > 0.0);
    }

    #[test]
    fn working_set_within_capacity_converges_to_hits() {
        let mut h = tiny(); // L2 = 1KB
        // stream 512B (8 lines) twice: second pass must fully hit L2
        for pass in 0..2 {
            for line in 0..8u64 {
                h.access(line * 64);
            }
            if pass == 0 {
                assert_eq!(h.llc_stats().misses, 8);
            }
        }
        assert_eq!(h.llc_stats().misses, 8, "no new LLC misses on re-stream");
    }

    #[test]
    fn presets_are_consistent() {
        assert_eq!(gem5_ex5_big().depth(), 2);
        assert_eq!(gem5_ex5_big_l3().depth(), 3);
        assert_eq!(l1_only().depth(), 1);
        assert_eq!(rpi4_a72().depth(), 2);
        assert_eq!(with_l2_size(8 << 20).level_config(1).size, 8 << 20);
    }
}

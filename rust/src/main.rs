//! `fullpack` — leader entrypoint: figure regeneration, measured
//! benches, the serving-engine demo, and PJRT artifact execution.

use fullpack::cli::{Args, USAGE};
use fullpack::coordinator::{
    Engine, EngineConfig, RouterConfig, SchedulerConfig, StoreConfig, SubmitError,
};
use fullpack::costmodel::Method;
use fullpack::figures::{e2e, ondevice, sweeps, SIZES, SIZES_QUICK};
use fullpack::kernels::{GemvKernel, KernelRegistry};
use fullpack::models::{
    CompiledModel, DeepSpeech, DeepSpeechConfig, Model, ModelRegistry, ModelSize,
};
use fullpack::pack::Variant;
#[cfg(feature = "pjrt")]
use fullpack::runtime::{Runtime, Tensor};
use fullpack::sim::CachePreset;
use fullpack::util::error::{anyhow, bail, Result};

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if args.flag("help") || args.positionals.is_empty() {
        print!("{USAGE}");
        return;
    }
    let r = match args.pos(0).unwrap() {
        "simulate" => cmd_simulate(&args),
        "bench" => cmd_bench(&args),
        "serve" => cmd_serve(&args),
        "workload" => cmd_workload(&args),
        "models" => cmd_models(&args),
        "kernels" => cmd_kernels(&args),
        "artifact" => cmd_artifact(&args),
        other => Err(anyhow!("unknown command {other:?}\n\n{USAGE}")),
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn sizes(args: &Args) -> &'static [usize] {
    if args.flag("quick") {
        &SIZES_QUICK
    } else {
        &SIZES
    }
}

fn emit_csv(dir: Option<&str>, report: &sweeps::FigureReport) -> Result<()> {
    let Some(dir) = dir else { return Ok(()) };
    std::fs::create_dir_all(dir)?;
    for (name, table) in &report.tables {
        let slug: String = name
            .chars()
            .map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect();
        std::fs::write(format!("{dir}/{}_{slug}.csv", report.id), table.to_csv())?;
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    if args.flag("show-config") {
        let preset = CachePreset::parse(args.opt_or("preset", "gem5"))
            .ok_or_else(|| anyhow!("unknown preset"))?;
        let h = preset.build();
        println!("preset: {} ({} levels, mem latency {} cycles)", preset.name(), h.depth(), h.mem_latency);
        for i in 0..h.depth() {
            let c = h.level_config(i);
            println!(
                "  {}: {} KB, {}B lines, {}-way, {}-cycle hits",
                c.name,
                c.size / 1024,
                c.line,
                c.assoc,
                c.hit_latency
            );
        }
        return Ok(());
    }
    let which = args.pos(1).unwrap_or("all");
    if which == "model" {
        return cmd_simulate_model(args);
    }
    let sz = sizes(args);
    let csv = args.opt("csv");
    let run = |id: &str| -> Result<()> {
        let report = match id {
            "fig4" => sweeps::fig4(sz),
            "fig5" => sweeps::fig5(sz),
            "fig6" => sweeps::fig6(sz),
            "fig7" => sweeps::fig7(sz),
            "fig8" => sweeps::fig8(sz),
            "fig12" => sweeps::fig12(sz),
            "fig13" => sweeps::fig13(sz),
            // not a paper figure: the GEMM tier's memory-aware
            // batch x size amortization sweep (DESIGN.md §9)
            "gemm-batch" => sweeps::fig_gemm_batch(sz),
            // not a paper figure: the LUT tier's table-vs-L1 crossover
            // sweep on the portable core (DESIGN.md §13)
            "lut-crossover" => sweeps::fig_lut_crossover(sz),
            // not a paper figure: the real-ISA tier's gain over the
            // staged/SWAR kernels on the wide cores (DESIGN.md §15)
            "isa-crossover" => sweeps::fig_isa_crossover(sz),
            "fig10" | "fig1" => {
                let (table, totals) = e2e::fig10(DeepSpeechConfig::FULL);
                println!("=== fig10 (DeepSpeech per-layer breakdown, simulated) ===\n");
                table.print();
                let base = totals.iter().find(|(n, _)| n == "Ruy-W8A8").unwrap().1;
                println!("\nend-to-end speedup vs Ruy-W8A8:");
                for (name, total) in &totals {
                    println!("  {name:>16}: {:.2}x", base / total);
                }
                let share = e2e::lstm_share(Method::RuyW8A8, Method::RuyW8A8, DeepSpeechConfig::FULL);
                println!("\nfig1 headline — LSTM share of Ruy-W8A8 runtime: {:.0}%", share * 100.0);
                if let Some(dir) = csv {
                    std::fs::create_dir_all(dir)?;
                    std::fs::write(format!("{dir}/fig10_breakdown.csv"), table.to_csv())?;
                }
                return Ok(());
            }
            other => bail!("unknown figure {other:?}"),
        };
        report.print();
        emit_csv(csv, &report)
    };
    if which == "all" {
        for id in [
            "fig4",
            "fig5",
            "fig6",
            "fig7",
            "fig8",
            "fig10",
            "fig12",
            "fig13",
            "gemm-batch",
            "lut-crossover",
            "isa-crossover",
        ] {
            run(id)?;
        }
        Ok(())
    } else {
        run(which)
    }
}

fn parse_size(args: &Args) -> Result<ModelSize> {
    let s = args.opt_or("size", "full");
    ModelSize::parse(s).ok_or_else(|| anyhow!("--size {s:?} (expected full|tiny)"))
}

fn parse_variant(args: &Args, default: &str) -> Result<Variant> {
    Variant::parse(args.opt_or("variant", default)).map_err(|e| anyhow!("bad variant: {e}"))
}

/// `simulate model`: whole-model method comparison on the cost model
/// (`costmodel::simulate_model`) — per-layer breakdown for one zoo
/// graph, or the cross-zoo e2e table for `--name all`.
fn cmd_simulate_model(args: &Args) -> Result<()> {
    let size = parse_size(args)?;
    let variant = parse_variant(args, "w4a8")?;
    let name = args.opt_or("name", "all");
    if name == "all" {
        let (table, rows) = e2e::fig_e2e_zoo(size, variant);
        println!(
            "=== model zoo end-to-end (simulated, {} size, variant {variant}) ===\n",
            size.name()
        );
        table.print();
        println!("\nend-to-end speedup vs all-Ruy baseline:");
        for (model, base, fp) in &rows {
            println!("  {model:>16}: {:.2}x", base / fp);
        }
        return Ok(());
    }
    let graph = ModelRegistry::global()
        .build(name, size, variant, 7)
        .map_err(|e| anyhow!("--name: {e}"))?;
    let (table, base, fp) = e2e::model_breakdown(&graph);
    println!("=== {} (simulated per-layer breakdown) ===\n", graph.describe());
    table.print();
    println!(
        "\ntotals: ruy-w8a8 {:.2} Mcyc, fullpack {:.2} Mcyc -> {:.2}x",
        base / 1e6,
        fp / 1e6,
        base / fp
    );
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    match args.pos(1) {
        Some("fig11") => {
            let ms = args.opt_usize("ms", 30).map_err(|e| anyhow!(e))? as u64;
            println!("=== fig11 (measured CNN FC layers; host = RPi-4 substitution) ===\n");
            let (table, geo) = ondevice::fig11(3, ms);
            table.print();
            println!("\ngeomean speedups vs ruy-w8a8:");
            for (m, g) in geo {
                println!("  {m:>14}: {g:.2}x");
            }
            Ok(())
        }
        Some("deepspeech") => {
            let variant = Variant::parse(args.opt_or("variant", "w4a8"))
                .map_err(|e| anyhow!("bad variant: {e}"))?;
            let cfg = if args.flag("tiny") { DeepSpeechConfig::TINY } else { DeepSpeechConfig::FULL };
            let mut model = DeepSpeech::new(cfg, variant, 7);
            if let Some(kernel) = args.opt("kernel") {
                // explicit registry selection overrides the paper rule
                model = model.with_lstm_kernel(kernel).map_err(|e| anyhow!("--kernel: {e}"))?;
            }
            println!("lstm kernel: {}", model.lstm_kernel_name());
            model.intra_op_threads =
                args.opt_usize("intra-threads", 1).map_err(|e| anyhow!(e))?;
            let frames: Vec<f32> =
                (0..cfg.time_steps * cfg.n_input).map(|i| (i as f32 * 0.01).sin()).collect();
            // warmup + 5 measured runs, keep the best
            let mut best: Option<Vec<(String, u128)>> = None;
            let mut best_total = u128::MAX;
            model.forward_timed(&frames);
            for _ in 0..5 {
                let (_, times) = model.forward_timed(&frames);
                let total: u128 = times.iter().map(|(_, t)| t).sum();
                if total < best_total {
                    best_total = total;
                    best = Some(times);
                }
            }
            let times = best.unwrap();
            println!(
                "deepspeech {variant} (T={} input={} hidden={}): total {:.3} ms",
                cfg.time_steps,
                cfg.n_input,
                cfg.n_hidden,
                best_total as f64 / 1e6
            );
            if args.flag("breakdown") {
                for (name, ns) in &times {
                    println!(
                        "  {name:>5}: {:>9.3} ms  ({:>4.1}%)",
                        *ns as f64 / 1e6,
                        *ns as f64 / best_total as f64 * 100.0
                    );
                }
            }
            Ok(())
        }
        _ => bail!("bench expects fig11|deepspeech"),
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let requests = args.opt_usize("requests", 32).map_err(|e| anyhow!(e))?;
    // config file takes precedence over ad-hoc flags
    let (mut engine_cfg, roster) = if let Some(path) = args.opt("config") {
        let fc = fullpack::coordinator::FileConfig::load(path)?;
        (fc.engine, fc.models)
    } else {
        let variant = parse_variant(args, "w4a8")?;
        let workers = args.opt_usize("workers", 2).map_err(|e| anyhow!(e))?;
        let size = if args.flag("tiny") { ModelSize::Tiny } else { ModelSize::Full };
        let zoo_name = args.opt_or("model", "deepspeech").to_string();
        (
            EngineConfig {
                workers,
                sched: SchedulerConfig::default(),
                router: RouterConfig::default(),
                store: StoreConfig::default(),
            },
            vec![fullpack::coordinator::ModelSpec {
                name: zoo_name.clone(),
                model: zoo_name,
                variant,
                size,
                seed: 7,
                pin: false,
            }],
        )
    };
    // scheduler knobs layer on top of either source
    engine_cfg.sched.max_batch = args
        .opt_usize("max-batch", engine_cfg.sched.max_batch)
        .map_err(|e| anyhow!(e))?;
    engine_cfg.sched.max_queue = args
        .opt_usize("max-queue", engine_cfg.sched.max_queue)
        .map_err(|e| anyhow!(e))?;
    engine_cfg.sched.slo = std::time::Duration::from_millis(
        args.opt_usize("slo-ms", engine_cfg.sched.slo.as_millis() as usize)
            .map_err(|e| anyhow!(e))? as u64,
    );
    // residency knobs (DESIGN.md §14): --resident-mb puts the model
    // store under a modeled byte budget, --pin exempts one model
    if let Some(mb) = args.opt("resident-mb") {
        let mb: u64 = mb.parse().map_err(|_| anyhow!("--resident-mb: bad number {mb:?}"))?;
        engine_cfg.store.budget_bytes = Some(mb << 20);
    }
    if args.flag("fixed-deadline") {
        // the pre-scheduler policy: no cost-model seals, no admission
        // control — the before-side of the EXPERIMENTS.md comparison
        engine_cfg.sched.cost_flush = false;
        engine_cfg.sched.shed_over_budget = false;
    }
    let intra = args.opt_usize("intra-threads", 1).map_err(|e| anyhow!(e))?;
    let engine = Engine::new(engine_cfg);
    let mut first: Option<(String, usize)> = None;
    // --kernel re-binds scan cells; in a mixed fleet it applies to the
    // models that have them and must not abort the feed-forward members
    let kernel_applied = std::cell::Cell::new(false);
    let register = |name: &str,
                        graph: fullpack::models::ModelGraph,
                        first: &mut Option<(String, usize)>|
     -> Result<()> {
        let mut model = CompiledModel::compile(graph).map_err(|e| anyhow!("{name}: {e}"))?;
        if let Some(kernel) = args.opt("kernel") {
            if model.cell_kernel_name().is_some() {
                model = model.with_cell_kernel(kernel).map_err(|e| anyhow!("--kernel: {e}"))?;
                kernel_applied.set(true);
            }
        }
        model.intra_op_threads = intra;
        println!(
            "registered {name}: {} (cell kernel {})",
            model.describe(),
            model.cell_kernel_name().unwrap_or("-")
        );
        let input_len = model.input_len();
        engine
            .register_model(name, model)
            .map_err(|e| anyhow!("register {name:?}: {e}"))?;
        first.get_or_insert((name.to_string(), input_len));
        Ok(())
    };
    for spec in &roster {
        let graph = ModelRegistry::global()
            .build(&spec.model, spec.size, spec.variant, spec.seed)
            .map_err(|e| anyhow!("model {:?}: {e}", spec.name))?;
        register(&spec.name, graph, &mut first)?;
        if spec.pin {
            engine.pin_model(&spec.name).map_err(|e| anyhow!("pin {:?}: {e}", spec.name))?;
        }
    }
    // a runtime-assembled layer graph joins the same roster
    if let Some(path) = args.opt("model-manifest") {
        let graph = fullpack::runtime::manifest::load_model_graph(path)?;
        let name = graph.name.clone();
        register(&name, graph, &mut first)?;
    }
    if let Some(kernel) = args.opt("kernel") {
        if !kernel_applied.get() {
            bail!("--kernel {kernel:?}: no registered model has scan cells to re-bind");
        }
    }
    if let Some(name) = args.opt("pin") {
        engine.pin_model(name).map_err(|e| anyhow!("--pin {name:?}: {e}"))?;
    }
    let (target, input_len) = first.ok_or_else(|| anyhow!("config has no models"))?;
    println!(
        "serving {target} ({} workers, {requests} requests, slo {}ms{})...",
        engine_cfg.workers,
        engine_cfg.sched.slo.as_millis(),
        if engine_cfg.sched.cost_flush { "" } else { ", fixed-deadline policy" },
    );
    let frames: Vec<f32> = (0..input_len).map(|i| (i as f32 * 0.01).sin()).collect();
    // typed sheds are an expected outcome under admission control, not
    // a demo failure: collect what was admitted, report what was shed
    let mut rxs = Vec::with_capacity(requests);
    let mut shed = 0u64;
    for _ in 0..requests {
        match engine.try_submit(&target, frames.clone()) {
            Ok(rx) => rxs.push(rx),
            Err(SubmitError::Rejected(rej)) => {
                shed += 1;
                println!("  {rej}");
            }
            Err(e) => bail!("{e}"),
        }
    }
    // manifest-driven hot-swap while v1 batches may still be in flight:
    // the swap is atomic (new admissions see v2), the pending receivers
    // below drain on whichever version their batch was dispatched with
    if let Some(path) = args.opt("swap-manifest") {
        let v = fullpack::runtime::manifest::swap_model_from_manifest(&engine, path)?;
        println!("hot-swapped from {path}: now serving v{v}");
    }
    for rx in rxs {
        rx.recv().map_err(|_| anyhow!("engine dropped request"))??;
    }
    if shed > 0 {
        println!("{shed}/{requests} requests shed by admission control (typed, retry-hinted)");
    }
    println!("metrics: {}", engine.metrics().summary());
    let (gemv, gemm) = engine.router().counts();
    println!("router:  gemv(FullPack)={gemv} gemm(Ruy)={gemm}");
    let st = engine.store().stats();
    println!(
        "store:   {}/{} models resident, {:.1} MB modeled{}",
        st.resident_models,
        st.models,
        st.resident_bytes as f64 / 1e6,
        match st.budget_bytes {
            Some(b) => format!(" (budget {:.1} MB)", b as f64 / 1e6),
            None => " (unbudgeted)".to_string(),
        },
    );
    engine.shutdown();
    Ok(())
}

/// `workload gen-mixes|run|sweep`: the scenario-mix harness
/// (DESIGN.md §11).  `gen-mixes` samples concrete mix files from a mix
/// space, `run` replays one mix (live engine by default), `sweep`
/// samples + runs a whole set and emits the `bench-serve/v3` document.
fn cmd_workload(args: &Args) -> Result<()> {
    use fullpack::figures::serve::{fig_serve_dispatch, fig_serve_latency};
    use fullpack::workload::{
        build_report, run_live, run_virtual, write_serve_json, MixReport, MixSpace, WorkloadMix,
    };

    let host = std::env::var("HOSTNAME").unwrap_or_else(|_| "unknown-host".into());
    let load_space = |args: &Args| -> Result<MixSpace> {
        match args.opt("space") {
            Some(path) => MixSpace::load(path),
            None => Ok(MixSpace::default_space()),
        }
    };

    match args.pos(1) {
        Some("gen-mixes") => {
            let space = load_space(args)?;
            let seed = args.opt_usize("seed", 7).map_err(|e| anyhow!(e))? as u64;
            let count = args.opt_usize("count", 8).map_err(|e| anyhow!(e))?;
            let dir = args.opt_or("out", "mixes");
            std::fs::create_dir_all(dir)?;
            for mix in space.sample_all(seed, count) {
                let path = format!("{dir}/{}.json", mix.name);
                mix.save(&path)?;
                println!(
                    "{path}: {} x{} clients, {} ({} requests)",
                    mix.arrival.describe(),
                    mix.clients,
                    mix.models.iter().map(|m| m.spec.name.as_str()).collect::<Vec<_>>().join("+"),
                    mix.total_requests(),
                );
            }
            println!("sampled with seed {seed}; same seed => byte-identical files");
            Ok(())
        }
        Some("run") => {
            let path = args
                .opt("mix")
                .ok_or_else(|| anyhow!("workload run --mix F.json [--virtual] [--verify]"))?;
            let mix = WorkloadMix::load(path)?;
            let (mode, trace) = if args.flag("virtual") {
                ("virtual-costmodel", run_virtual(&mix)?)
            } else {
                ("live", run_live(&mix, args.flag("verify"))?)
            };
            let report = build_report(&mix, &trace)?;
            let reports = [report];
            fig_serve_latency(&reports).print();
            println!();
            fig_serve_dispatch(&reports).print();
            let r = &reports[0];
            println!(
                "\n{}: {}/{} completed ({} shed, {} errors), p99 {} us, {:.1} rps",
                r.mix, r.completed, r.issued, r.shed, r.errors, r.p99_us, r.throughput_rps
            );
            if let Some(out) = args.opt("out") {
                let note = format!("single mix {path}");
                write_serve_json(out, mode, &host, &note, &reports)?;
                println!("wrote {out}");
            }
            Ok(())
        }
        Some("sweep") => {
            let space = load_space(args)?;
            let seed = args.opt_usize("seed", 7).map_err(|e| anyhow!(e))? as u64;
            let count = args.opt_usize("count", 8).map_err(|e| anyhow!(e))?;
            let live = args.flag("live");
            let mode = if live { "live" } else { "virtual-costmodel" };
            let out = args.opt_or("out", "BENCH_serve.json");
            let mut reports: Vec<MixReport> = Vec::with_capacity(count);
            for mix in space.sample_all(seed, count) {
                let trace =
                    if live { run_live(&mix, false)? } else { run_virtual(&mix)? };
                let report = build_report(&mix, &trace)?;
                println!(
                    "{}: {}/{} completed, p99 {} us",
                    report.mix, report.completed, report.issued, report.p99_us
                );
                reports.push(report);
            }
            println!("\n=== fig-serve: latency/throughput ===\n");
            fig_serve_latency(&reports).print();
            println!("\n=== fig-serve: dispatch mix ===\n");
            fig_serve_dispatch(&reports).print();
            let space_desc = args.opt_or("space", "default space");
            let note = format!("mix sweep: seed {seed}, {count} mixes from {space_desc}");
            write_serve_json(out, mode, &host, &note, &reports)?;
            println!("\nwrote {out} (schema bench-serve/v3, source {mode})");
            Ok(())
        }
        _ => bail!("workload expects: gen-mixes | run --mix F.json | sweep"),
    }
}

fn cmd_models(args: &Args) -> Result<()> {
    match (args.pos(1), args.pos(2)) {
        (Some("list"), _) | (None, _) => {
            let reg = ModelRegistry::global();
            let mut t = fullpack::util::bench::Table::new(vec!["model", "topology"]);
            for e in reg.iter() {
                t.row(vec![e.name.to_string(), e.blurb.to_string()]);
            }
            println!("{} registered model graphs:\n", reg.len());
            t.print();
            println!(
                "\nshow one with `models show NAME`; serve one with `serve --model NAME`"
            );
            Ok(())
        }
        (Some("show"), Some(name)) => {
            let size = parse_size(args)?;
            let variant = parse_variant(args, "w4a8")?;
            let graph = ModelRegistry::global()
                .build(name, size, variant, 7)
                .map_err(|e| anyhow!("{e}"))?;
            let model = CompiledModel::compile(graph.clone()).map_err(|e| anyhow!("{e}"))?;
            println!("{}", model.describe());
            let plans = model.plan_names();
            for node in &graph.nodes {
                let backend = plans
                    .iter()
                    .find(|(n, _)| n == &node.name)
                    .map(|(_, b)| *b)
                    .unwrap_or("-");
                println!(
                    "  {:>8}: {:<5} {:>5}x{:<5} {:?} -> {backend}",
                    node.name,
                    node.op.label(),
                    node.z,
                    node.k,
                    node.op.role(),
                );
            }
            println!(
                "weight footprint ({}): {:.1} MB",
                graph.variant,
                model.weight_footprint() as f64 / 1e6
            );
            Ok(())
        }
        // `models store`: pack compiled zoo weights into FPCK images —
        // the zero-copy load path the model store's cold admissions
        // exercise (DESIGN.md §14)
        (Some("store"), sub) => {
            if let Some(path) = args.opt("inspect") {
                let img = fullpack::pack::serialize::WeightsImage::open(path)?;
                println!(
                    "{path}: FPCK image, {} tensors, {} payload bytes",
                    img.len(),
                    img.total_bytes()
                );
                for name in img.names() {
                    let w = img.get(name).unwrap();
                    println!("  {name:>20}: {:>5}x{:<5} ({} bytes)", w.rows(), w.k(), w.footprint());
                }
                return Ok(());
            }
            let dir = sub.ok_or_else(|| {
                anyhow!("models store <out-dir> [--size S] [--variant V] | models store --inspect F.fpck")
            })?;
            let size = parse_size(args)?;
            let variant = parse_variant(args, "w4a8")?;
            std::fs::create_dir_all(dir)?;
            for e in ModelRegistry::global().iter() {
                let graph = ModelRegistry::global()
                    .build(e.name, size, variant, 7)
                    .map_err(|err| anyhow!("{}: {err}", e.name))?;
                let model = CompiledModel::compile(graph).map_err(|err| anyhow!("{}: {err}", e.name))?;
                let entries = model.weight_entries();
                let tensors: Vec<(&str, &fullpack::kernels::Weights)> =
                    entries.iter().map(|(n, w)| (n.as_str(), *w)).collect();
                let path = format!("{dir}/{}.fpck", e.name);
                fullpack::pack::serialize::save_image(&tensors, &path)?;
                println!(
                    "{path}: {} tensors, {} resident bytes",
                    tensors.len(),
                    model.resident_bytes()
                );
            }
            println!("reload one with `WeightsImage::open` (zero-copy borrowed views)");
            Ok(())
        }
        _ => bail!("models expects: list | show <zoo-name> | store <out-dir>"),
    }
}

fn cmd_kernels(args: &Args) -> Result<()> {
    match args.pos(1) {
        Some("list") | None => {
            let reg = KernelRegistry::global();
            let mut t = fullpack::util::bench::Table::new(vec![
                "kernel",
                "native variants",
                "modeled as",
                "packed acts",
            ]);
            for kernel in reg.iter() {
                let mut variants: Vec<String> = Variant::PAPER_VARIANTS
                    .iter()
                    .chain(std::iter::once(&Variant::parse("w8a8").unwrap()))
                    .filter(|v| kernel.supports(**v))
                    .map(|v| v.name())
                    .collect();
                variants.sort();
                t.row(vec![
                    kernel.name().to_string(),
                    variants.join(","),
                    kernel.cost_method().map_or("-".into(), |m| m.label()),
                    if kernel.packs_activations() { "yes".into() } else { "no".to_string() },
                ]);
            }
            println!("{} registered kernels:\n", reg.len());
            t.print();
            println!("\nselect one with `bench deepspeech --kernel NAME` or `serve --kernel NAME`");
            Ok(())
        }
        _ => bail!("kernels expects: list"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn cmd_artifact(_args: &Args) -> Result<()> {
    bail!(
        "this build has no PJRT runtime: rebuild with `--features pjrt` \
         (requires the xla bindings; see Cargo.toml)"
    )
}

#[cfg(feature = "pjrt")]
fn cmd_artifact(args: &Args) -> Result<()> {
    let dir = args.opt_or("dir", "artifacts");
    let rt = Runtime::load(dir)?;
    match args.pos(1) {
        Some("list") => {
            println!("{} artifacts (VL={}):", rt.manifest().artifacts.len(), rt.manifest().vl);
            for a in &rt.manifest().artifacts {
                println!(
                    "  {:<28} kind={:<10} variant={:<5} inputs={}",
                    a.name,
                    a.kind,
                    a.variant,
                    a.inputs.len()
                );
            }
            Ok(())
        }
        Some("run") => {
            let name = args.pos(2).ok_or_else(|| anyhow!("artifact run <name>"))?;
            let meta = rt
                .manifest()
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?
                .clone();
            // synthesize small deterministic inputs per the manifest
            let inputs: Vec<Tensor> = meta
                .inputs
                .iter()
                .map(|spec| {
                    let n = spec.elems();
                    match spec.dtype {
                        fullpack::runtime::DType::S8 => Tensor::s8(
                            (0..n).map(|i| (i % 3) as i8 - 1).collect(),
                            spec.shape.clone(),
                        ),
                        fullpack::runtime::DType::U8 => Tensor::u8(
                            (0..n).map(|i| (i % 16) as u8).collect(),
                            spec.shape.clone(),
                        ),
                        fullpack::runtime::DType::S32 => Tensor::s32(vec![0; n], spec.shape.clone()),
                        fullpack::runtime::DType::F32 => Tensor::f32(
                            (0..n).map(|i| (i as f32 * 0.01).sin() * 0.1).collect(),
                            spec.shape.clone(),
                        ),
                    }
                })
                .collect();
            let t0 = std::time::Instant::now();
            let out = rt.execute(name, &inputs)?;
            println!(
                "{name}: {} outputs in {:.2} ms (compile included on first call)",
                out.len(),
                t0.elapsed().as_secs_f64() * 1e3
            );
            for (i, t) in out.iter().enumerate() {
                println!("  out[{i}]: {} x{} {:?}", t.dtype().name(), t.len(), &t.shape);
            }
            Ok(())
        }
        _ => bail!("artifact expects list|run"),
    }
}

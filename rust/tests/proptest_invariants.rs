//! Property-based invariants (proptest_lite — DESIGN.md §7) across the
//! coordinator substrates: packing, kernels, quantization, the cache
//! simulator, the admission scheduler and the router.

use fullpack::coordinator::{Scheduler, SchedulerConfig};
use fullpack::kernels::{
    gemv, pack_activations, ActVec, GemmKernel, GemvKernel, KernelRegistry, SwarKernel, Weights,
};
use fullpack::pack::{pack, pad_rows, unpack, BitWidth, PackedMatrix, Variant};
use fullpack::quant::{dequantize, quantize};
use fullpack::sim::{replay_gemv, CachePreset, GemvTraffic};
use fullpack::util::proptest_lite::{run_prop, Gen};

const SUB_BITS: [BitWidth; 3] = [BitWidth::B4, BitWidth::B2, BitWidth::B1];

#[test]
fn prop_pack_roundtrip_arbitrary_lengths() {
    run_prop(200, |g| {
        let bits = *g.pick(&SUB_BITS);
        let (lo, hi) = bits.value_range();
        let x = g.vec_i8_in(lo, hi, 0, 700);
        let packed = pack(&x, bits).unwrap();
        unpack(&packed, bits, x.len()).unwrap() == x
    });
}

#[test]
fn prop_pack_density_exact() {
    // zero spacer bits: every packed byte carries exactly 8/bits values
    run_prop(100, |g| {
        let bits = *g.pick(&SUB_BITS);
        let (lo, hi) = bits.value_range();
        let x = g.vec_i8_in(lo, hi, 1, 500);
        let packed = pack(&x, bits).unwrap();
        packed.len() == bits.padded_len(x.len()) / bits.elems_per_byte()
    });
}

#[test]
fn prop_gemv_matches_oracle_every_variant() {
    run_prop(60, |g| {
        let variant = Variant::PAPER_VARIANTS[g.usize_in(0, 8)];
        let z = g.usize_in(1, 24);
        let k = g.usize_in(1, 300);
        let kp = variant.padded_depth(k);
        let (wlo, whi) = variant.w.value_range();
        let (alo, ahi) = variant.a.value_range();
        let mut w = vec![0i8; z * kp];
        for r in 0..z {
            for c in 0..k {
                w[r * kp + c] = g.i8_in(wlo, whi);
            }
        }
        let mut a = vec![0i8; kp];
        for c in 0..k {
            a[c] = g.i8_in(alo, ahi);
        }
        let wp = PackedMatrix::from_i8(&w, z, kp, variant.w).unwrap();
        let packed_a;
        let act = if variant.a.is_sub_byte() {
            packed_a = pack_activations(&a, variant.a).unwrap();
            ActVec::Packed { bytes: &packed_a, bits: variant.a }
        } else {
            ActVec::I8(&a)
        };
        let mut out = vec![0i32; z];
        gemv(&wp, act, &mut out).unwrap();
        (0..z).all(|r| {
            let oracle: i32 =
                w[r * kp..(r + 1) * kp].iter().zip(&a).map(|(&x, &y)| x as i32 * y as i32).sum();
            out[r] == oracle
        })
    });
}

#[test]
fn prop_gemv_transpose_symmetry_w8a8() {
    // gemv(W, a)[r] == gemv(W^T rowwise trick): dot products commute
    run_prop(50, |g| {
        let n = g.usize_in(1, 64);
        let w = g.vec_i8_in(-128, 127, n * n, n * n);
        let a = g.vec_i8_in(-128, 127, n, n);
        let wp = PackedMatrix::from_i8(&w, n, n, BitWidth::B8).unwrap();
        let mut wt = vec![0i8; n * n];
        for i in 0..n {
            for j in 0..n {
                wt[j * n + i] = w[i * n + j];
            }
        }
        let wtp = PackedMatrix::from_i8(&wt, n, n, BitWidth::B8).unwrap();
        let mut y1 = vec![0i32; n];
        let mut y2 = vec![0i32; n];
        gemv(&wp, ActVec::I8(&a), &mut y1).unwrap();
        gemv(&wtp, ActVec::I8(&a), &mut y2).unwrap();
        // y1 . a-ones == sum over matrix == y2 . a-ones when a == 1?  Use
        // the weaker but always-true invariant: sum_r y1[r]*1 with unit
        // acts equals total matrix sum both ways.
        let ones = vec![1i8; n];
        let mut s1 = vec![0i32; n];
        let mut s2 = vec![0i32; n];
        gemv(&wp, ActVec::I8(&ones), &mut s1).unwrap();
        gemv(&wtp, ActVec::I8(&ones), &mut s2).unwrap();
        s1.iter().map(|&v| v as i64).sum::<i64>() == s2.iter().map(|&v| v as i64).sum::<i64>()
    });
}

#[test]
fn prop_quantize_bounded_error() {
    run_prop(100, |g| {
        let bits = *g.pick(&[BitWidth::B8, BitWidth::B4, BitWidth::B2]);
        let n = g.usize_in(1, 200);
        let x: Vec<f32> = (0..n).map(|_| (g.f32_unit() - 0.5) * 20.0).collect();
        let q = quantize(&x, bits);
        let (lo, hi) = bits.value_range();
        if !q.values.iter().all(|&v| v >= lo && v <= hi) {
            return false;
        }
        let deq = dequantize(&q.values, q.scale);
        x.iter().zip(&deq).all(|(a, b)| (a - b).abs() <= q.scale * 0.5 + 1e-5)
    });
}

#[test]
fn prop_cache_sim_invariants() {
    // misses <= accesses at every level; inner-level accesses >= outer;
    // deterministic replay
    run_prop(40, |g| {
        let z = g.usize_in(1, 64);
        let k = g.usize_in(1, 2048);
        let t = GemvTraffic {
            z,
            w_bytes_per_row: k.max(1),
            a_bytes: k.max(1),
            batch: g.usize_in(1, 4),
            out_elem_bytes: 4,
        };
        let mut h1 = CachePreset::Gem5Ex5Big.build();
        let lat1 = replay_gemv(&mut h1, &t);
        let mut h2 = CachePreset::Gem5Ex5Big.build();
        let lat2 = replay_gemv(&mut h2, &t);
        if lat1 != lat2 {
            return false;
        }
        let l1 = h1.level_stats(0);
        let llc = h1.llc_stats();
        l1.misses <= l1.accesses && llc.misses <= llc.accesses && llc.accesses <= l1.accesses
            // LLC sees exactly the L1 misses in a 2-level inclusive
            // hierarchy
            && llc.accesses == l1.misses
    });
}

#[test]
fn prop_working_set_fits_no_steady_misses() {
    // if total bytes fit the LLC, a second identical replay misses ~never
    run_prop(30, |g| {
        let z = g.usize_in(1, 32);
        let k = g.usize_in(64, 4096);
        let t = GemvTraffic { z, w_bytes_per_row: k, a_bytes: k, batch: 1, out_elem_bytes: 4 };
        if t.weight_bytes() + t.a_bytes > (1 << 20) {
            return true; // only test the fits case
        }
        let mut h = CachePreset::Gem5Ex5Big.build();
        replay_gemv(&mut h, &t);
        let cold = h.llc_stats().misses;
        replay_gemv(&mut h, &t);
        h.llc_stats().misses == cold
    });
}

#[test]
fn prop_pack_gemm_unpack_roundtrip() {
    // layout invariant across the GEMV/GEMM boundary: packing a weight
    // matrix (plain or SWAR side-table layout), running a batched GEMM
    // over it, and unpacking it back must (a) recover the zero-padded
    // original exactly and (b) leave every GEMM column equal to the
    // logical oracle — so a layout change cannot silently corrupt
    // batched results
    let reg = KernelRegistry::global();
    run_prop(40, |g| {
        let bits = *g.pick(&SUB_BITS);
        let v = Variant::new(bits, BitWidth::B8);
        let z = g.usize_in(1, 12);
        let k = g.usize_in(1, 200);
        let batch = g.usize_in(1, 5);
        let (lo, hi) = bits.value_range();
        let w = g.vec_i8_in(lo, hi, z * k, z * k);

        // plain packed layout via the GEMM backend
        let gemm_name = fullpack::kernels::fullpack_gemm_kernel_name(v).unwrap();
        let backend = reg.get_gemm(gemm_name).unwrap();
        let wts = backend.prepare(&w, z, k).unwrap();
        let kp = wts.k_padded();
        let padded = pad_rows(&w, z, k, kp);
        let wp = wts.as_packed().unwrap();
        if wp.unpack_all() != padded {
            return false; // pack→unpack lost or moved an element
        }

        // SWAR side-table layout: same packed bytes + exact row sums
        let swar = SwarKernel::new(v).unwrap();
        let swts = swar.prepare(&w, z, k).unwrap();
        if swts.as_packed().unwrap().unpack_all() != padded {
            return false;
        }
        let Weights::SwarPacked { row_sums, .. } = &swts else { return false };
        let sums_ok = (0..z).all(|r| {
            row_sums[r] == w[r * k..(r + 1) * k].iter().map(|&x| x as i64).sum::<i64>()
        });
        if !sums_ok {
            return false;
        }

        // GEMM over both layouts matches the logical oracle per column
        let cols: Vec<Vec<i8>> = (0..batch)
            .map(|_| {
                let mut col = g.vec_i8_in(-128, 127, k, k);
                col.resize(kp, 0);
                col
            })
            .collect();
        let col_refs: Vec<&[i8]> = cols.iter().map(|c| c.as_slice()).collect();
        let mut out = vec![0i32; z * batch];
        backend.gemm(&wts, &col_refs, &mut out).unwrap();
        let mut swar_out = vec![0i32; z * batch];
        swar.gemm(&swts, &col_refs, &mut swar_out).unwrap();
        if out != swar_out {
            return false;
        }
        (0..batch).all(|c| {
            (0..z).all(|r| {
                let oracle: i32 = w[r * k..(r + 1) * k]
                    .iter()
                    .zip(&cols[c][..k])
                    .map(|(&wv, &av)| wv as i32 * av as i32)
                    .sum();
                out[c * z + r] == oracle
            })
        })
    });
}

#[test]
fn prop_scheduler_fifo_and_lossless_drain() {
    run_prop(60, |g| {
        let max_batch = g.usize_in(1, 8);
        let n = g.usize_in(0, 40);
        // deadline/budget rules disarmed: only Full seals and the
        // shutdown drain move requests, so the property is pure FIFO
        let mut s: Scheduler<usize> = Scheduler::new(
            SchedulerConfig {
                max_batch,
                max_wait: std::time::Duration::from_secs(100),
                max_queue: 1024,
                slo: std::time::Duration::from_secs(100),
                cost_flush: false,
                shed_over_budget: false,
            },
            Box::new(|_, group| group as u64),
        );
        let m = s.register("m");
        for i in 0..n {
            if s.submit(m, i, i as u64).is_err() {
                return false;
            }
        }
        s.seal_all_drained();
        let mut drained = Vec::new();
        while let Some(d) = s.pop(n as u64, None) {
            if d.entries.len() > max_batch {
                return false;
            }
            drained.extend(d.entries.into_iter().map(|(item, _)| item));
        }
        s.is_empty() && drained == (0..n).collect::<Vec<_>>()
    });
}

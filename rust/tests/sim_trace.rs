//! Integration suite for the GEMM memory-trace tier (`sim::trace`,
//! PR 4): the one-weight-pass LLC invariant at LLC-spilling sizes,
//! exact per-operand access accounting, the GEMV/GEMM consistency
//! contract at batch 1, and the output-write accounting regression.
//!
//! Registered in Cargo.toml and the CI per-suite matrix — deleting
//! this file fails the build loudly (PR 3 convention).

use fullpack::costmodel::{simulate_gemm_traced, CoreModel, Method};
use fullpack::sim::{
    replay_gemm, replay_gemm_restream, replay_gemv_traced, CachePreset, GemmTraffic, GemvTraffic,
    Hierarchy,
};

fn gem5() -> Hierarchy {
    CachePreset::Gem5Ex5Big.build()
}

fn w4a8_traffic(z: usize, k: usize) -> GemvTraffic {
    GemvTraffic { z, w_bytes_per_row: k / 2, a_bytes: k, batch: 1, out_elem_bytes: 4 }
}

#[test]
fn gemm_strictly_fewer_weight_misses_when_weights_spill_the_llc() {
    // 4096x4096 w4a8: 8MB of packed weights against the 2MB L2.  The
    // batched call reads them once; `batch` repeated GEMVs read them
    // `batch` times, and nothing survives the LLC between passes.
    let t = w4a8_traffic(4096, 4096);
    for batch in [2usize, 4, 8] {
        let mut hg = gem5();
        let g = replay_gemm(&mut hg, &GemmTraffic::from_gemv(&t, batch));
        let mut hr = gem5();
        let r = replay_gemm_restream(&mut hr, &t, batch);
        assert!(
            g.weights.llc_misses < r.weights.llc_misses,
            "batch {batch}: gemm weight misses {} !< restream {}",
            g.weights.llc_misses,
            r.weights.llc_misses
        );
        // the advantage is roughly the full factor of `batch`: every
        // re-streamed pass cold-misses the spilled matrix again
        assert!(
            g.weights.llc_misses * (batch as u64) <= r.weights.llc_misses + r.weights.llc_misses / 4,
            "batch {batch}: expected ~{batch}x weight-miss gap ({} vs {})",
            g.weights.llc_misses,
            r.weights.llc_misses
        );
        // and it shows in the aggregate hierarchy stats too
        assert!(hg.llc_stats().misses < hr.llc_stats().misses, "batch {batch}");
    }
}

#[test]
fn exact_per_operand_access_accounting() {
    // line-granular counts are closed-form: z rows x ceil(bytes/line)
    // lines — the GEMM walk re-reads the weight row once per
    // COL_TILE-column tile (the kernel's loop; L1-resident re-walks) —
    // batch columns each, one first-touch access per output line
    let line = 64usize;
    let ct = fullpack::kernels::fullpack_gemm::COL_TILE;
    for (z, k, batch) in [(16usize, 256usize, 1usize), (33, 128, 3), (7, 64, 5)] {
        let t = w4a8_traffic(z, k);
        let wlines = (k / 2).div_ceil(line) as u64;
        let alines = k.div_ceil(line) as u64;
        let out_lines = (z * batch * 4).div_ceil(line) as u64;
        let tiles = batch.div_ceil(ct) as u64;

        let mut h = gem5();
        let g = replay_gemm(&mut h, &GemmTraffic::from_gemv(&t, batch));
        assert_eq!(
            g.weights.accesses,
            z as u64 * wlines * tiles,
            "gemm weights z={z} k={k} b={batch}"
        );
        assert_eq!(g.acts.accesses, z as u64 * alines * batch as u64, "gemm acts");
        assert_eq!(g.outs.accesses, out_lines, "gemm outs");

        let mut h = gem5();
        let r = replay_gemm_restream(&mut h, &t, batch);
        assert_eq!(r.weights.accesses, z as u64 * wlines * batch as u64, "restream weights");
        assert_eq!(r.acts.accesses, z as u64 * alines * batch as u64, "restream acts");
        assert_eq!(r.outs.accesses, out_lines, "restream outs");

        // the hierarchy saw exactly what the operand split claims
        assert_eq!(h.level_stats(0).accesses, r.total_accesses());
    }
}

#[test]
fn gemm_traffic_consistent_with_gemv_at_batch_1() {
    // one column is one GEMV: identical access stream, identical
    // per-operand stats, identical end-state hierarchy counters
    for (z, k) in [(64usize, 512usize), (33, 192), (2048, 2048)] {
        let t = w4a8_traffic(z, k);
        let mut hv = gem5();
        let v = replay_gemv_traced(&mut hv, &t);
        let mut hg = gem5();
        let g = replay_gemm(&mut hg, &GemmTraffic::from_gemv(&t, 1));
        assert_eq!(v, g, "replay stats diverge at z={z} k={k}");
        for lvl in 0..hv.depth() {
            assert_eq!(hv.level_stats(lvl), hg.level_stats(lvl), "level {lvl} z={z} k={k}");
        }
    }
}

#[test]
fn output_accounting_counts_every_line_exactly_once() {
    // regression (PR 4 satellite): the pre-fix crossing test recorded
    // zero output accesses whenever z·batch·4 < 64 and always dropped
    // the trailing partial line
    for (z, batch, want) in [
        (1usize, 1usize, 1u64), // 4 bytes: sub-line output
        (4, 2, 1),              // 32 bytes: still one line
        (16, 1, 1),             // exactly one line
        (17, 1, 2),             // one line + 4 trailing bytes
        (33, 1, 3),             // 132 bytes -> 3 lines
        (64, 3, 12),            // aligned multi-line
    ] {
        let t = GemvTraffic { batch, ..w4a8_traffic(z, 64) };
        let mut h = gem5();
        let s = replay_gemv_traced(&mut h, &t);
        assert_eq!(s.outs.accesses, want, "z={z} batch={batch}");
        // the GEMM shape agrees on the same total
        let mut h = gem5();
        let g = replay_gemm(&mut h, &GemmTraffic::from_gemv(&w4a8_traffic(z, 64), batch));
        assert_eq!(g.outs.accesses, want, "gemm z={z} batch={batch}");
    }
}

#[test]
fn simulate_gemm_inherits_the_invariant() {
    // the costmodel wiring preserves the trace-level contract: the
    // FullPack GEMM method does one weight pass per call, the repeated
    // protocol's weight misses scale with batch (steady state included)
    let core = CoreModel::ex5_big();
    let preset = CachePreset::Gem5Ex5Big;
    let (z, k) = (4096, 4096);
    let gemm =
        |b| simulate_gemm_traced(Method::fullpack_gemm("w4a8"), z, k, b, preset, &core, 3).1;
    let repeated =
        |b| simulate_gemm_traced(Method::fullpack("w4a8"), z, k, b, preset, &core, 3).1;
    let (g2, g8) = (gemm(2), gemm(8));
    let (r2, r8) = (repeated(2), repeated(8));
    // GEMM weight misses are flat in batch; repeated grows ~linearly
    assert!(g8.weights.llc_misses <= g2.weights.llc_misses + g2.weights.llc_misses / 8);
    assert!(r8.weights.llc_misses > r2.weights.llc_misses * 3);
    // and the batched call beats the repeated protocol outright
    assert!(g8.total_llc_misses() < r8.total_llc_misses());
}

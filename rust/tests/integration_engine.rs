//! Engine-level integration: the whole L3 stack (admission scheduler →
//! router → sharded workers → model → kernels) under concurrent load,
//! failure injection, and policy variations.

use fullpack::coordinator::{
    Engine, EngineConfig, FlushReason, RouterConfig, Scheduler, SchedulerConfig, ShedReason,
    StoreConfig, SubmitError,
};
use fullpack::models::{DeepSpeech, DeepSpeechConfig};
use fullpack::pack::Variant;

fn frames(cfg: DeepSpeechConfig) -> Vec<f32> {
    (0..cfg.time_steps * cfg.n_input).map(|i| (i as f32 * 0.013).sin()).collect()
}

fn engine_with(variant: &str, workers: usize, max_queue: usize) -> Engine {
    let e = Engine::new(EngineConfig {
        workers,
        sched: SchedulerConfig {
            max_batch: 4,
            max_wait: std::time::Duration::from_millis(1),
            max_queue,
            // lax SLO: these tests exercise load/batching, not the
            // admission controller's budget rule
            slo: std::time::Duration::from_secs(5),
            ..SchedulerConfig::default()
        },
        router: RouterConfig::default(),
        store: StoreConfig::default(),
    });
    e.register_model(
        "ds",
        DeepSpeech::new(DeepSpeechConfig::TINY, Variant::parse(variant).unwrap(), 11),
    )
    .unwrap();
    e
}

#[test]
fn sustained_concurrent_load_all_variants() {
    for variant in ["w4a8", "w8a4", "w4a4", "w2a8", "w8a2", "w2a2", "w1a8", "w8a1", "w1a1"] {
        let e = engine_with(variant, 3, 256);
        let f = frames(DeepSpeechConfig::TINY);
        let rxs: Vec<_> = (0..24).map(|_| e.try_submit("ds", f.clone()).unwrap()).collect();
        for rx in rxs {
            let r = rx.recv().unwrap().unwrap();
            assert!(r.logits.iter().all(|x| x.is_finite()), "{variant}");
        }
        assert_eq!(e.metrics().completed.load(std::sync::atomic::Ordering::Relaxed), 24);
        e.shutdown();
    }
}

#[test]
fn multiple_models_coexist() {
    let e = engine_with("w4a8", 2, 64);
    e.register_model(
        "ds-w1a1",
        DeepSpeech::new(DeepSpeechConfig::TINY, Variant::parse("w1a1").unwrap(), 11),
    )
    .unwrap();
    let f = frames(DeepSpeechConfig::TINY);
    let a = e.infer("ds", f.clone()).unwrap();
    let b = e.infer("ds-w1a1", f).unwrap();
    assert_ne!(a.logits, b.logits, "different quantization, different outputs");
}

#[test]
fn model_hot_swap() {
    let e = engine_with("w4a8", 1, 64);
    let f = frames(DeepSpeechConfig::TINY);
    let before = e.infer("ds", f.clone()).unwrap().logits;
    // silent replacement by re-registration is refused; replacing a
    // live model is the explicit versioned swap (DESIGN.md §14)
    assert!(e
        .register_model(
            "ds",
            DeepSpeech::new(DeepSpeechConfig::TINY, Variant::parse("w4a8").unwrap(), 99),
        )
        .is_err());
    let v = e
        .swap_model(
            "ds",
            DeepSpeech::new(DeepSpeechConfig::TINY, Variant::parse("w4a8").unwrap(), 99),
            None,
        )
        .unwrap();
    assert_eq!(v, 2, "first swap of a v1 registration");
    let after = e.infer("ds", f).unwrap().logits;
    assert_ne!(before, after, "hot-swapped weights take effect");
}

#[test]
fn backpressure_rejects_cleanly_and_recovers() {
    // one worker, tiny queue: flood and expect some rejections but no
    // deadlock and full recovery afterwards
    let e = engine_with("w4a8", 1, 4);
    let f = frames(DeepSpeechConfig::TINY);
    let mut accepted = Vec::new();
    let mut rejected = 0;
    for _ in 0..64 {
        match e.try_submit("ds", f.clone()) {
            Ok(rx) => accepted.push(rx),
            Err(SubmitError::Rejected(r)) => {
                // refusals arrive typed, with the modeled retry hint
                assert!(
                    matches!(r.reason, ShedReason::QueueFull | ShedReason::OverBudget),
                    "{r}"
                );
                assert!(r.retry_after_us >= 1, "retry hint present: {r}");
                assert_eq!(r.model, "ds");
                rejected += 1;
            }
            Err(e @ SubmitError::UnknownModel(_)) => panic!("ds is registered: {e}"),
        }
    }
    for rx in accepted {
        rx.recv().unwrap().unwrap();
    }
    // engine still serves after the flood
    assert!(e.infer("ds", f).is_ok());
    assert!(rejected > 0 || e.metrics().completed.load(std::sync::atomic::Ordering::Relaxed) >= 64);
}

#[test]
fn errors_do_not_poison_workers() {
    let e = engine_with("w4a8", 1, 64);
    let f = frames(DeepSpeechConfig::TINY);
    for _ in 0..3 {
        assert!(e.infer("missing-model", f.clone()).is_err());
        assert!(e.infer("ds", vec![1.0; 7]).is_err()); // bad shape
    }
    let ok = e.infer("ds", f).unwrap();
    assert!(!ok.logits.is_empty());
    assert_eq!(e.metrics().errors.load(std::sync::atomic::Ordering::Relaxed), 6);
}

#[test]
fn router_counts_reflect_topology() {
    let e = engine_with("w2a2", 2, 64);
    let f = frames(DeepSpeechConfig::TINY);
    for _ in 0..4 {
        e.infer("ds", f.clone()).unwrap();
    }
    let (gemv, gemm) = e.router().counts();
    // per request: 1 LSTM layer -> gemv path, 5 FC layers -> gemm path
    assert_eq!(gemv, 4);
    assert_eq!(gemm, 20);
}

#[test]
fn producer_threads_every_reply_exactly_once_and_dispatch_counts_sum() {
    use std::sync::atomic::Ordering::Relaxed;
    // N producer threads × M requests each, against one worker with a
    // generous flush deadline so concurrent arrivals coalesce: every
    // reply arrives exactly once, and the batched + singleton dispatch
    // counters sum to the request total
    let producers = 4usize;
    let per_producer = 6usize;
    let total = (producers * per_producer) as u64;
    let e = Engine::new(EngineConfig {
        workers: 1,
        sched: SchedulerConfig {
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(100),
            max_queue: 256,
            slo: std::time::Duration::from_secs(5),
            ..SchedulerConfig::default()
        },
        router: RouterConfig::default(),
        store: StoreConfig::default(),
    });
    e.register_model(
        "ds",
        DeepSpeech::new(DeepSpeechConfig::TINY, Variant::parse("w4a8").unwrap(), 11),
    )
    .unwrap();
    let e = std::sync::Arc::new(e);
    let f = frames(DeepSpeechConfig::TINY);
    let baseline = e.infer("ds", f.clone()).unwrap().logits;

    let mut handles = Vec::new();
    for p in 0..producers {
        let e = e.clone();
        let f = f.clone();
        handles.push(std::thread::spawn(move || {
            let mut ids = Vec::new();
            let rxs: Vec<_> = (0..per_producer)
                .map(|_| e.try_submit("ds", f.clone()).expect("queue sized for the load"))
                .collect();
            for rx in rxs {
                let r = rx.recv().expect("engine never drops accepted work").expect("infer ok");
                ids.push(r.id);
            }
            (p, ids)
        }));
    }
    let mut all_ids = Vec::new();
    for h in handles {
        let (_, ids) = h.join().unwrap();
        assert_eq!(ids.len(), per_producer);
        all_ids.extend(ids);
    }
    // exactly once: every accepted request answered, no id twice
    all_ids.sort_unstable();
    all_ids.dedup();
    assert_eq!(all_ids.len(), producers * per_producer);
    assert_eq!(e.metrics().completed.load(Relaxed), total + 1); // + baseline
    // dispatch accounting: batched + singleton == total handed to workers
    let (batched, singleton) = e.metrics().dispatch_counts();
    assert_eq!(batched + singleton, total + 1);
    // with 24 concurrent arrivals against one worker and a 100ms
    // deadline, at least one flush must have coalesced ≥2 requests into
    // a single GemmKernel::gemm dispatch
    assert!(batched >= 2, "no multi-request GEMM dispatch (batched={batched})");
    assert!(
        e.metrics().batched_dispatches.load(Relaxed) >= 1,
        "no batched dispatch recorded"
    );
    // batched execution is bit-identical to the singleton baseline
    let again = e.infer("ds", f).unwrap().logits;
    assert_eq!(again, baseline);
}

#[test]
fn batched_dispatch_replies_match_singleton_results() {
    use std::sync::atomic::Ordering::Relaxed;
    // force one guaranteed multi-request flush: fill the batcher to
    // max_batch while the single worker is still parked on the deadline
    let e = Engine::new(EngineConfig {
        workers: 1,
        sched: SchedulerConfig {
            max_batch: 4,
            max_wait: std::time::Duration::from_millis(200),
            max_queue: 64,
            slo: std::time::Duration::from_secs(5),
            ..SchedulerConfig::default()
        },
        router: RouterConfig::default(),
        store: StoreConfig::default(),
    });
    e.register_model(
        "ds",
        DeepSpeech::new(DeepSpeechConfig::TINY, Variant::parse("w2a8").unwrap(), 11),
    )
    .unwrap();
    let f = frames(DeepSpeechConfig::TINY);
    // distinct inputs so a scatter bug (column/request swap) is visible
    let inputs: Vec<Vec<f32>> = (0..4)
        .map(|r| f.iter().map(|&x| x + r as f32 * 0.25).collect())
        .collect();
    let rxs: Vec<_> = inputs.iter().map(|f| e.try_submit("ds", f.clone()).unwrap()).collect();
    let replies: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
    // each reply equals a fresh singleton inference of ITS OWN input
    for (input, reply) in inputs.iter().zip(&replies) {
        let single = e.infer("ds", input.clone()).unwrap();
        assert_eq!(reply.logits, single.logits);
    }
    let (batched, singleton) = e.metrics().dispatch_counts();
    assert_eq!(batched + singleton, 8);
    assert_eq!(e.metrics().completed.load(Relaxed), 8);
}

#[test]
fn scheduler_generic_over_payload() {
    // the scheduler is reusable for arbitrary work items: max_batch 2
    // seals {a, b} Full at admission, c keeps forming
    let mut s: Scheduler<String> = Scheduler::new(
        SchedulerConfig {
            max_batch: 2,
            max_wait: std::time::Duration::from_secs(10),
            max_queue: 8,
            ..SchedulerConfig::default()
        },
        Box::new(|_, _| 1),
    );
    let m = s.register("strings");
    assert!(!s.submit(m, "a".into(), 0).unwrap().sealed);
    assert!(s.submit(m, "b".into(), 0).unwrap().sealed);
    assert!(!s.submit(m, "c".into(), 0).unwrap().sealed);
    let d = s.pop(0, None).unwrap();
    assert_eq!(d.reason, FlushReason::Full);
    let items: Vec<String> = d.entries.into_iter().map(|(item, _)| item).collect();
    assert_eq!(items, vec!["a".to_string(), "b".to_string()]);
    assert!(s.has_forming() && !s.has_sealed());
}

//! Engine-level integration: the whole L3 stack (batcher → router →
//! workers → model → kernels) under concurrent load, failure injection,
//! and policy variations.

use fullpack::coordinator::{
    Batcher, BatcherConfig, Engine, EngineConfig, RouterConfig,
};
use fullpack::models::{DeepSpeech, DeepSpeechConfig};
use fullpack::pack::Variant;

fn frames(cfg: DeepSpeechConfig) -> Vec<f32> {
    (0..cfg.time_steps * cfg.n_input).map(|i| (i as f32 * 0.013).sin()).collect()
}

fn engine_with(variant: &str, workers: usize, max_queue: usize) -> Engine {
    let e = Engine::new(EngineConfig {
        workers,
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: std::time::Duration::from_millis(1),
            max_queue,
        },
        router: RouterConfig::default(),
    });
    e.register_model(
        "ds",
        DeepSpeech::new(DeepSpeechConfig::TINY, Variant::parse(variant).unwrap(), 11),
    );
    e
}

#[test]
fn sustained_concurrent_load_all_variants() {
    for variant in ["w4a8", "w8a4", "w4a4", "w2a8", "w8a2", "w2a2", "w1a8", "w8a1", "w1a1"] {
        let e = engine_with(variant, 3, 256);
        let f = frames(DeepSpeechConfig::TINY);
        let rxs: Vec<_> = (0..24).map(|_| e.submit("ds", f.clone()).unwrap()).collect();
        for rx in rxs {
            let r = rx.recv().unwrap().unwrap();
            assert!(r.logits.iter().all(|x| x.is_finite()), "{variant}");
        }
        assert_eq!(e.metrics().completed.load(std::sync::atomic::Ordering::Relaxed), 24);
        e.shutdown();
    }
}

#[test]
fn multiple_models_coexist() {
    let e = engine_with("w4a8", 2, 64);
    e.register_model(
        "ds-w1a1",
        DeepSpeech::new(DeepSpeechConfig::TINY, Variant::parse("w1a1").unwrap(), 11),
    );
    let f = frames(DeepSpeechConfig::TINY);
    let a = e.infer("ds", f.clone()).unwrap();
    let b = e.infer("ds-w1a1", f).unwrap();
    assert_ne!(a.logits, b.logits, "different quantization, different outputs");
}

#[test]
fn model_hot_swap() {
    let e = engine_with("w4a8", 1, 64);
    let f = frames(DeepSpeechConfig::TINY);
    let before = e.infer("ds", f.clone()).unwrap().logits;
    // replace the model under the same name (new seed)
    e.register_model(
        "ds",
        DeepSpeech::new(DeepSpeechConfig::TINY, Variant::parse("w4a8").unwrap(), 99),
    );
    let after = e.infer("ds", f).unwrap().logits;
    assert_ne!(before, after, "hot-swapped weights take effect");
}

#[test]
fn backpressure_rejects_cleanly_and_recovers() {
    // one worker, tiny queue: flood and expect some rejections but no
    // deadlock and full recovery afterwards
    let e = engine_with("w4a8", 1, 4);
    let f = frames(DeepSpeechConfig::TINY);
    let mut accepted = Vec::new();
    let mut rejected = 0;
    for _ in 0..64 {
        match e.submit("ds", f.clone()) {
            Ok(rx) => accepted.push(rx),
            Err(_) => rejected += 1,
        }
    }
    for rx in accepted {
        rx.recv().unwrap().unwrap();
    }
    // engine still serves after the flood
    assert!(e.infer("ds", f).is_ok());
    assert!(rejected > 0 || e.metrics().completed.load(std::sync::atomic::Ordering::Relaxed) >= 64);
}

#[test]
fn errors_do_not_poison_workers() {
    let e = engine_with("w4a8", 1, 64);
    let f = frames(DeepSpeechConfig::TINY);
    for _ in 0..3 {
        assert!(e.infer("missing-model", f.clone()).is_err());
        assert!(e.infer("ds", vec![1.0; 7]).is_err()); // bad shape
    }
    let ok = e.infer("ds", f).unwrap();
    assert!(!ok.logits.is_empty());
    assert_eq!(e.metrics().errors.load(std::sync::atomic::Ordering::Relaxed), 6);
}

#[test]
fn router_counts_reflect_topology() {
    let e = engine_with("w2a2", 2, 64);
    let f = frames(DeepSpeechConfig::TINY);
    for _ in 0..4 {
        e.infer("ds", f.clone()).unwrap();
    }
    let (gemv, gemm) = e.router().counts();
    // per request: 1 LSTM layer -> gemv path, 5 FC layers -> gemm path
    assert_eq!(gemv, 4);
    assert_eq!(gemm, 20);
}

#[test]
fn batcher_generic_over_payload() {
    // the batcher is reusable for arbitrary work items
    let mut b: Batcher<String> = Batcher::new(BatcherConfig {
        max_batch: 2,
        max_wait: std::time::Duration::from_secs(10),
        max_queue: 8,
    });
    b.push("a".into()).unwrap();
    b.push("b".into()).unwrap();
    b.push("c".into()).unwrap();
    let (batch, _) = b.pop_batch(false).unwrap();
    assert_eq!(batch, vec!["a".to_string(), "b".to_string()]);
}

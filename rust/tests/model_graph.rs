//! Golden equivalence suite for the model IR (DESIGN.md §10):
//!
//! * `CompiledModel` over the DeepSpeech graph is **bit-identical** to
//!   the legacy `DeepSpeech::forward`/`forward_batch` — TINY across
//!   every paper variant (+ w8a8), FULL on the paper's headline
//!   variants — so the graph executor can replace the hand-written
//!   model without changing a single logit;
//! * zoo models check out against shape/oracle expectations;
//! * the engine serves a mixed fleet of three distinct zoo models
//!   through the one `Model` trait, with exactly-once replies and
//!   per-model dispatch metrics that sum to the request totals.

use fullpack::coordinator::{Engine, EngineConfig, RouterConfig, SchedulerConfig, StoreConfig};
use fullpack::models::{
    deepspeech_graph, CompiledModel, DeepSpeech, DeepSpeechConfig, Model, ModelRegistry,
    ModelSize,
};
use fullpack::pack::{BitWidth, Variant};
use fullpack::quant::requantize;

fn frames_for(len: usize, salt: usize) -> Vec<f32> {
    (0..len).map(|i| ((i + salt * 37) as f32 * 0.013).sin()).collect()
}

/// The repo's deterministic synthetic-weight generator (mirrors
/// `models::xorshift_vals`, which is crate-private by design — the test
/// re-derives it so oracle checks don't depend on the crate's own
/// generator being correct).
fn xorshift_vals(bits: BitWidth, n: usize, seed: u64) -> Vec<i8> {
    let (lo, hi) = bits.value_range();
    let span = (hi as i16 - lo as i16 + 1) as u64;
    let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    (0..n)
        .map(|_| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (lo as i16 + (s % span) as i16) as i8
        })
        .collect()
}

#[test]
fn compiled_deepspeech_bit_identical_tiny_all_variants() {
    let cfg = DeepSpeechConfig::TINY;
    let frames = frames_for(cfg.time_steps * cfg.n_input, 0);
    for vname in ["w4a8", "w8a4", "w4a4", "w2a8", "w8a2", "w2a2", "w1a8", "w8a1", "w1a1", "w8a8"]
    {
        let v = Variant::parse(vname).unwrap();
        let legacy = DeepSpeech::new(cfg, v, 7);
        let compiled = CompiledModel::compile(deepspeech_graph(cfg, v, 7)).unwrap();
        let (want, want_times) = legacy.forward_timed(&frames);
        let (got, got_times) = compiled.forward_timed(&frames);
        assert_eq!(got, want, "{vname}: logits diverge from the legacy model");
        // same layer labels in the same order
        let names = |ts: &[(String, u128)]| -> Vec<String> {
            ts.iter().map(|(n, _)| n.clone()).collect()
        };
        assert_eq!(names(&got_times), names(&want_times), "{vname}");
    }
}

#[test]
fn compiled_deepspeech_bit_identical_full() {
    // the paper-sized graph on the headline sub-byte variant; one
    // request keeps this inside tier-1 runtime
    let cfg = DeepSpeechConfig::FULL;
    let v = Variant::parse("w4a8").unwrap();
    let frames = frames_for(cfg.time_steps * cfg.n_input, 1);
    let want = DeepSpeech::new(cfg, v, 7).forward_timed(&frames).0;
    let got = CompiledModel::compile(deepspeech_graph(cfg, v, 7))
        .unwrap()
        .forward_timed(&frames)
        .0;
    assert_eq!(got, want, "FULL w4a8 logits diverge from the legacy model");
}

#[test]
fn compiled_deepspeech_batched_bit_identical() {
    let cfg = DeepSpeechConfig::TINY;
    for vname in ["w4a8", "w2a2", "w8a8"] {
        let v = Variant::parse(vname).unwrap();
        let legacy = DeepSpeech::new(cfg, v, 13);
        let compiled = CompiledModel::compile(deepspeech_graph(cfg, v, 13)).unwrap();
        let reqs: Vec<Vec<f32>> =
            (0..4).map(|r| frames_for(cfg.time_steps * cfg.n_input, r)).collect();
        let refs: Vec<&[f32]> = reqs.iter().map(|f| f.as_slice()).collect();
        let want = legacy.forward_batch(&refs);
        let got = compiled.forward_batch(&refs);
        assert_eq!(want.len(), got.len());
        for (r, ((wl, _), (gl, _))) in want.iter().zip(&got).enumerate() {
            assert_eq!(gl, wl, "{vname} request {r}");
        }
    }
}

#[test]
fn compiled_deepspeech_bit_identical_under_explicit_kernel_and_threads() {
    // kernel re-binding and intra-op sharding are orthogonal to the IR:
    // both executors stay in lockstep under them
    let cfg = DeepSpeechConfig::TINY;
    let v = Variant::parse("w4a8").unwrap();
    let frames = frames_for(cfg.time_steps * cfg.n_input, 2);
    let legacy = DeepSpeech::new(cfg, v, 7).with_lstm_kernel("fullpack-w4a8-swar").unwrap();
    let mut compiled = CompiledModel::compile(deepspeech_graph(cfg, v, 7))
        .unwrap()
        .with_cell_kernel("fullpack-w4a8-swar")
        .unwrap();
    assert_eq!(compiled.cell_kernel_name(), Some("fullpack-w4a8-swar"));
    assert_eq!(compiled.forward_timed(&frames).0, legacy.forward_timed(&frames).0);
    compiled.intra_op_threads = 3;
    assert_eq!(compiled.forward_timed(&frames).0, legacy.forward_timed(&frames).0);
}

#[test]
fn single_fc_graph_matches_hand_oracle() {
    // one FC node, no relu: out[r] = acc[r] * (s_w * s_act) + bias with
    // acc the plain integer GEMV over the quantized inputs
    use fullpack::models::ModelGraph;
    let v = Variant::parse("w4a8").unwrap();
    let (z, k) = (8usize, 16usize);
    let g = ModelGraph::new("one-fc", v, k, 1, 42).fc("fc", z, false);
    let (s_w, s_act) = (g.s_w, g.s_act);
    let m = CompiledModel::compile(g).unwrap();
    let x = frames_for(k, 3);
    let (got, _) = m.forward_timed(&x);
    // oracle: same quantization points, integer GEMV, same requantize
    let w = xorshift_vals(BitWidth::B4, z * k, 42);
    let (lo, hi) = v.a.value_range();
    let xq: Vec<i8> = x
        .iter()
        .map(|&f| (f / s_act).round().clamp(lo as f32, hi as f32) as i8)
        .collect();
    let want: Vec<f32> = (0..z)
        .map(|r| {
            let acc: i32 =
                (0..k).map(|c| w[r * k + c] as i32 * xq[c] as i32).sum();
            requantize(acc, s_w, s_act, 0.01)
        })
        .collect();
    assert_eq!(got, want);
}

#[test]
fn zoo_models_shape_and_determinism() {
    let v = Variant::parse("w4a8").unwrap();
    for name in ModelRegistry::global().names() {
        let g = ModelRegistry::global().build(name, ModelSize::Tiny, v, 11).unwrap();
        let frames = frames_for(g.input_len(), 5);
        let out_len = g.output_len();
        let m = CompiledModel::compile(g.clone()).unwrap();
        let (out, times) = m.forward_timed(&frames);
        assert_eq!(out.len(), out_len, "{name}");
        assert!(out.iter().all(|x| x.is_finite()), "{name}");
        assert_eq!(times.len(), g.nodes.len(), "{name}");
        // recompilation is deterministic
        let again = CompiledModel::compile(g).unwrap().forward_timed(&frames).0;
        assert_eq!(again, out, "{name}");
    }
}

fn tiny_compiled(name: &str, variant: &str, seed: u64) -> CompiledModel {
    let g = ModelRegistry::global()
        .build(name, ModelSize::Tiny, Variant::parse(variant).unwrap(), seed)
        .unwrap();
    CompiledModel::compile(g).unwrap()
}

#[test]
fn engine_serves_mixed_zoo_models_exactly_once_with_per_model_metrics() {
    use std::sync::atomic::Ordering::Relaxed;
    let e = Engine::new(EngineConfig {
        workers: 2,
        sched: SchedulerConfig {
            max_batch: 6,
            max_wait: std::time::Duration::from_millis(5),
            max_queue: 256,
            ..SchedulerConfig::default()
        },
        router: RouterConfig::default(),
        store: StoreConfig::default(),
    });
    // three distinct topologies behind the one Model trait
    let zoo = ["deepspeech", "mlp", "keyword-spotter"];
    for name in zoo {
        e.register_model(name, tiny_compiled(name, "w4a8", 11)).unwrap();
    }
    assert_eq!(e.model_names().len(), 3);
    let per_model = 8usize;
    let mut rxs = Vec::new();
    for name in zoo {
        let input_len = e.model(name).unwrap().input_len();
        for r in 0..per_model {
            rxs.push((name, e.try_submit(name, frames_for(input_len, r)).unwrap()));
        }
    }
    // exactly once: every reply arrives, ids unique, logits shaped
    let mut ids = Vec::new();
    for (name, rx) in rxs {
        let resp = rx.recv().unwrap().unwrap();
        let expect = e.model(name).unwrap().output_len();
        assert_eq!(resp.logits.len(), expect, "{name}");
        ids.push(resp.id);
    }
    ids.sort_unstable();
    ids.dedup();
    let total = (zoo.len() * per_model) as u64;
    assert_eq!(ids.len() as u64, total);
    assert_eq!(e.metrics().completed.load(Relaxed), total);
    assert_eq!(e.metrics().errors.load(Relaxed), 0);
    // per-model dispatch accounting sums to each model's request count,
    // and the engine-wide split is the per-model sum
    let (mut sum_b, mut sum_s) = (0u64, 0u64);
    for name in zoo {
        let (b, s) = e.metrics().model_dispatch_counts(name);
        assert_eq!(b + s, per_model as u64, "{name}: batched {b} + singleton {s}");
        let c = e.metrics().model_counters(name).unwrap();
        assert_eq!(c.completed, per_model as u64, "{name}");
        sum_b += b;
        sum_s += s;
    }
    assert_eq!(e.metrics().dispatch_counts(), (sum_b, sum_s));
    // every model surfaces in the one-line summary
    let summary = e.metrics().summary();
    for name in zoo {
        assert!(summary.contains(name), "summary missing {name}: {summary}");
    }
    e.shutdown();
}

#[test]
fn mixed_flush_groups_by_model_and_stays_bit_identical() {
    // one worker + a parked deadline so requests for two models
    // coalesce inside their per-model admission queues: each model's
    // batch must scatter bit-identical results
    let e = Engine::new(EngineConfig {
        workers: 1,
        sched: SchedulerConfig {
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(200),
            max_queue: 64,
            ..SchedulerConfig::default()
        },
        router: RouterConfig::default(),
        store: StoreConfig::default(),
    });
    e.register_model("ds", tiny_compiled("deepspeech", "w2a8", 5)).unwrap();
    e.register_model("kws", tiny_compiled("keyword-spotter", "w2a8", 5)).unwrap();
    let ds_len = e.model("ds").unwrap().input_len();
    let kws_len = e.model("kws").unwrap().input_len();
    let mut subs = Vec::new();
    for r in 0..4 {
        let (name, len) = if r % 2 == 0 { ("ds", ds_len) } else { ("kws", kws_len) };
        let f = frames_for(len, r);
        subs.push((name, f.clone(), e.try_submit(name, f).unwrap()));
    }
    for (name, f, rx) in subs {
        let got = rx.recv().unwrap().unwrap().logits;
        let single = e.model(name).unwrap().forward_timed(&f).0;
        assert_eq!(got, single, "{name}: batched flush diverged from singleton");
    }
    // both models recorded dispatches under their own names
    assert!(e.metrics().model_counters("ds").is_some());
    assert!(e.metrics().model_counters("kws").is_some());
    e.shutdown();
}

#[test]
fn legacy_and_compiled_models_coexist_in_one_engine() {
    // the Model trait serves both implementations side by side
    let e = Engine::new(EngineConfig::default());
    let cfg = DeepSpeechConfig::TINY;
    let v = Variant::parse("w4a8").unwrap();
    e.register_model("legacy", DeepSpeech::new(cfg, v, 7)).unwrap();
    e.register_model("graph", tiny_compiled("deepspeech", "w4a8", 7)).unwrap();
    let f = frames_for(cfg.time_steps * cfg.n_input, 9);
    let a = e.infer("legacy", f.clone()).unwrap().logits;
    let b = e.infer("graph", f).unwrap().logits;
    assert_eq!(a, b, "same graph, same seed: same logits through the engine");
    e.shutdown();
}

//! Differential suite for the LUT tier (DESIGN.md §13): on every
//! implemented variant, across unaligned depths and batch sizes,
//!
//!   `lut-* GEMV  ≡  fullpack-* sibling  ≡  naive oracle`
//!   `lut-*-gemm  ≡  per-column oracle`
//!
//! — the contract that makes the tier a drop-in registry citizen: same
//! prepared layout, bit-identical outputs, selected only when the cost
//! model says the table build amortizes.  Also pins foreign-layout
//! rejection and the modeled crossover the `CostModel` policy resolves
//! between the two families.

use fullpack::kernels::registry::fullpack_kernel_name;
use fullpack::kernels::testutil::{oracle_gemv, rngvals};
use fullpack::kernels::{
    pack_activations, ActVec, GemmKernel, GemvKernel, KernelRegistry, LayerShape, PlanBuilder,
    SelectPolicy, LUT_VARIANTS,
};
use fullpack::pack::{pad_rows, BitWidth, Variant};

/// Depths: below/at/above the 8-byte SWAR chunk and the packed group,
/// plus unaligned serving depths — each a distinct padding/tail shape
/// for the per-position table indexing.
const DEPTHS: [usize; 9] = [1, 7, 8, 9, 63, 64, 65, 127, 129];
/// Batches: singleton, the GEMM promotion threshold, a full flush.
const BATCHES: [usize; 3] = [1, 2, 16];

/// The activation argument a GEMV backend wants for a padded int8
/// column: packed sub-byte bytes when the kernel packs activations,
/// the plain column otherwise.
fn act_for<'a>(
    kernel: &std::sync::Arc<dyn GemvKernel>,
    col: &'a [i8],
    bits: BitWidth,
    packed: &'a mut Vec<u8>,
) -> ActVec<'a> {
    if kernel.packs_activations() {
        *packed = pack_activations(col, bits).unwrap();
        ActVec::Packed { bytes: packed, bits }
    } else {
        ActVec::I8(col)
    }
}

#[test]
fn every_lut_backend_matches_fullpack_sibling_and_oracle() {
    let reg = KernelRegistry::global();
    let mut covered = 0usize;
    for v in LUT_VARIANTS {
        let vname = v.name();
        let lut = reg.get(&format!("lut-{vname}")).expect("lut gemv registered");
        let fp = reg.get(fullpack_kernel_name(v)).expect("fullpack sibling registered");
        let gemm = reg.get_gemm(&format!("lut-{vname}-gemm")).expect("lut gemm registered");
        let z = 8usize;
        for (ki, &k) in DEPTHS.iter().enumerate() {
            for (bi, &batch) in BATCHES.iter().enumerate() {
                let seed = 4000 + (ki * 100 + bi * 10) as u64;
                let w = rngvals(v.w, z * k, seed);
                // one prepared artifact serves the whole family — the
                // layouts are asserted identical below by running both
                let wts = lut.prepare(&w, z, k).unwrap();
                let kp = wts.k_padded();
                let wpad = pad_rows(&w, z, k, kp);
                let cols: Vec<Vec<i8>> = (0..batch)
                    .map(|c| {
                        let mut col = rngvals(v.a, k, seed + 1 + c as u64);
                        col.resize(kp, 0);
                        col
                    })
                    .collect();
                // batched LUT GEMM vs the per-column oracle
                let refs: Vec<&[i8]> = cols.iter().map(|c| c.as_slice()).collect();
                let mut out = vec![0i32; z * batch];
                gemm.gemm(&wts, &refs, &mut out).unwrap();
                for (c, col) in cols.iter().enumerate() {
                    let oracle = oracle_gemv(&wpad, col, z, kp);
                    assert_eq!(
                        &out[c * z..(c + 1) * z],
                        oracle.as_slice(),
                        "lut-{vname}-gemm k={k} batch={batch} col {c}"
                    );
                    // per-column: LUT GEMV ≡ FullPack sibling ≡ oracle,
                    // on the same prepared weights
                    let mut packed = Vec::new();
                    let a = act_for(lut, col, v.a, &mut packed);
                    let mut via_lut = vec![0i32; z];
                    lut.gemv_at(&wts, a, &mut via_lut, 0).unwrap();
                    assert_eq!(via_lut, oracle, "lut-{vname} k={k} col {c}");
                    let mut packed_fp = Vec::new();
                    let a_fp = act_for(fp, col, v.a, &mut packed_fp);
                    let mut via_fp = vec![0i32; z];
                    fp.gemv_at(&wts, a_fp, &mut via_fp, 0).unwrap();
                    assert_eq!(via_fp, oracle, "fullpack-{vname} on lut weights k={k} col {c}");
                }
            }
        }
        covered += 1;
    }
    // floor: all four implemented variants ran the full grid
    assert_eq!(covered, 4, "LUT variant coverage shrank");
}

#[test]
fn lut_backends_reject_foreign_layouts() {
    let reg = KernelRegistry::global();
    let w = rngvals(BitWidth::B4, 8 * 64, 5);
    let col = vec![0i8; 64];
    let mut out = vec![0i32; 8];
    let mut outb = vec![0i32; 8];
    // the naive tier's unpacked layout and ULPPACK's spacer-lane layout
    // are both foreign to the packed-byte table indexing
    for donor in ["naive-w4a8", "ulppack-w4a4"] {
        let foreign = reg.get(donor).unwrap().prepare(&w, 8, 64).unwrap();
        let lut = reg.get("lut-w4a8").unwrap();
        assert!(lut.gemv_at(&foreign, ActVec::I8(&col), &mut out, 0).is_err(), "{donor}");
        let g = reg.get_gemm("lut-w4a8-gemm").unwrap();
        assert!(g.gemm(&foreign, &[col.as_slice()], &mut outb).is_err(), "{donor} gemm");
    }
    // int8-packed weights: sub-byte only (the table IS the unpack)
    let w8 = reg.get("ruy-w8a8").unwrap().prepare(&w, 8, 64).unwrap();
    assert!(reg.get("lut-w4a8").unwrap().gemv_at(&w8, ActVec::I8(&col), &mut out, 0).is_err());
}

/// The crossover pin the cost-model tests assert at the `Method` level
/// (`costmodel::tests::lut_crossover_amortized_build_vs_l1_pressure`),
/// here driven through the planner's `CostModel` policy.  The registry
/// is restricted to the two contenders so the pin stays about the
/// LUT-vs-FullPack trade, not about whichever third tier sits nearby.
#[test]
fn cost_model_policy_resolves_the_lut_crossover() {
    let global = KernelRegistry::global();
    let mut reg = KernelRegistry::empty();
    reg.register(global.get("fullpack-w4a8").unwrap().clone());
    reg.register(global.get("lut-w4a8").unwrap().clone());
    let v = Variant::parse("w4a8").unwrap();
    let pick = |policy: SelectPolicy, z: usize, k: usize| {
        PlanBuilder::new(LayerShape { z, k, batch: 1 }, v)
            .policy(policy)
            .build_in(&reg)
            .unwrap()
    };
    // portable core, many rows, L1-resident table: the build amortizes
    // and the gather loop beats the penalized staged lane loops
    let p = pick(SelectPolicy::cost_model_portable(), 2048, 128);
    assert_eq!(p.kernel_name(), "lut-w4a8");
    // ... and the selected plan is executable end to end
    let (z, k) = (2048usize, 128usize);
    let w = rngvals(v.w, z * k, 91);
    let a = rngvals(v.a, k, 92);
    let wts = p.prepare_weights(&w).unwrap();
    let mut out = vec![0i32; z];
    p.execute(&wts, &a, &mut out).unwrap();
    let kp = v.padded_depth(k);
    let mut ap = a.clone();
    ap.resize(kp, 0);
    assert_eq!(out, oracle_gemv(&pad_rows(&w, z, k, kp), &ap, z, kp));
    // few rows: the per-call table build dominates — FullPack wins
    assert_eq!(pick(SelectPolicy::cost_model_portable(), 128, 128).kernel_name(), "fullpack-w4a8");
    // deep rows: the 1MB table thrashes L1 — FullPack wins
    assert_eq!(pick(SelectPolicy::cost_model_portable(), 2048, 2048).kernel_name(), "fullpack-w4a8");
    // a well-vectorized core: FullPack wins even in LUT's best regime
    assert_eq!(pick(SelectPolicy::cost_model(), 2048, 128).kernel_name(), "fullpack-w4a8");
}

//! Registry conformance suite: every registered kernel, on every paper
//! variant it supports (plus W8A8), across unaligned depths, must match
//! the scalar oracle when driven through the `Plan` API — the contract
//! that makes "add a backend" safe as one registry entry.
//!
//! Also proves the Router→Plan redesign is behavior-preserving: the new
//! plan selection reproduces the old two-way path decisions (FullPack
//! GEMV vs Ruy GEMM) for the paper's §4.6 policy.

use fullpack::coordinator::{OpDesc, Router, RouterConfig};
use fullpack::kernels::testutil::{oracle_gemv, pad_rows, rngvals};
use fullpack::kernels::{KernelRegistry, LayerShape, PlanBuilder, SelectPolicy};
use fullpack::pack::Variant;

const DEPTHS: [usize; 4] = [1, 17, 127, 129];

fn variants_under_test() -> Vec<Variant> {
    let mut v = Variant::PAPER_VARIANTS.to_vec();
    v.push(Variant::parse("w8a8").unwrap());
    v
}

/// Run `kernel` on a `z × k` layer of `variant` data through a Plan and
/// compare with the oracle.  `exact` distinguishes integer kernels from
/// the f32 stand-ins (exact only inside f32's 2^24 integer range — the
/// small shapes here stay inside it).
fn check(kernel: &str, variant: Variant, z: usize, k: usize, seed: u64) {
    let plan = PlanBuilder::new(LayerShape { z, k, batch: 1 }, variant)
        .policy(SelectPolicy::Explicit(kernel.to_string()))
        .build()
        .unwrap_or_else(|e| panic!("{kernel} {variant} k={k}: {e}"));
    let w = rngvals(variant.w, z * k, seed);
    let a = rngvals(variant.a, k, seed + 1);
    let weights = plan.prepare_weights(&w).expect("prepare");
    let mut out = vec![0i32; z];
    plan.execute(&weights, &a, &mut out).expect("execute");
    let kp = weights.k_padded();
    let wp = pad_rows(&w, z, k, kp);
    let mut ap = a.clone();
    ap.resize(kp, 0);
    assert_eq!(out, oracle_gemv(&wp, &ap, z, kp), "{kernel} {variant} z={z} k={k}");
}

#[test]
fn every_kernel_matches_oracle_on_supported_variants() {
    let reg = KernelRegistry::global();
    let mut covered = 0usize;
    for kernel in reg.iter() {
        for variant in variants_under_test() {
            if !kernel.supports(variant) {
                continue;
            }
            for (i, k) in DEPTHS.iter().enumerate() {
                check(kernel.name(), variant, 8, *k, 1000 + i as u64);
            }
            covered += 1;
        }
    }
    // floor: 9 fullpack + 3 naive + 3 ulppack + (4 i8 + 3 f32) × w8a8;
    // new backends only grow the count
    assert!(covered >= 22, "kernel×variant coverage shrank: {covered}");
}

#[test]
fn every_paper_variant_has_a_native_kernel() {
    let reg = KernelRegistry::global();
    for v in Variant::PAPER_VARIANTS {
        let names: Vec<_> = reg.supporting(v).iter().map(|k| k.name()).collect();
        assert!(
            names.iter().any(|n| n.starts_with("fullpack-")),
            "{v}: no FullPack kernel ({names:?})"
        );
    }
}

#[test]
fn larger_shapes_and_row_parallel_agree() {
    // deeper/wider layers + the plan's thread budget: sharded execution
    // must stay bit-identical across every paper variant
    for v in Variant::PAPER_VARIANTS {
        let (z, k) = (1024usize, 160usize);
        let plan = PlanBuilder::new(LayerShape { z, k, batch: 1 }, v)
            .threads(4)
            .build()
            .unwrap();
        assert!(plan.is_fullpack(), "{v}");
        let w = rngvals(v.w, z * k, 77);
        let a = rngvals(v.a, k, 78);
        let wts = plan.prepare_weights(&w).unwrap();
        let mut out = vec![0i32; z];
        plan.execute(&wts, &a, &mut out).unwrap();
        let kp = wts.k_padded();
        let wp = pad_rows(&w, z, k, kp);
        let mut ap = a.clone();
        ap.resize(kp, 0);
        assert_eq!(out, oracle_gemv(&wp, &ap, z, kp), "{v}");
    }
}

/// The old `Router::route` truth table (paper §4.6), replayed against
/// the Plan-emitting router: the old FullPack-GEMV path ⇔ a
/// `fullpack-*` kernel, the old Ruy-GEMM path ⇔ `ruy-w8a8`.
#[test]
fn router_plans_reproduce_old_path_decisions() {
    let cases: &[(usize, &str, bool)] = &[
        // (batch, variant, expected old Path == FullPackGemv)
        (1, "w4a8", true),   // single-batch sub-byte LSTM step
        (1, "w2a2", true),
        (1, "w1a1", true),
        (16, "w4a8", false), // batch-16 FC → Ruy GEMM
        (2, "w1a8", false),
        (1, "w8a8", false),  // 8-bit always on the baseline
        (16, "w8a8", false),
    ];
    let r = Router::new(RouterConfig::default());
    for &(batch, vname, fullpack) in cases {
        let plan = r
            .plan(&OpDesc { batch, z: 2048, k: 2048, variant: Variant::parse(vname).unwrap() })
            .unwrap();
        if fullpack {
            assert_eq!(plan.kernel_name(), format!("fullpack-{vname}"), "batch={batch}");
        } else {
            assert_eq!(plan.kernel_name(), "ruy-w8a8", "{vname} batch={batch}");
            assert_eq!(plan.exec_variant, Variant::parse("w8a8").unwrap());
        }
    }
    let (gemv, gemm) = r.counts();
    assert_eq!((gemv, gemm), (3, 4));

    // the ablation switch forces the baseline path, as the old router did
    let off = Router::new(RouterConfig { disable_fullpack: true, ..Default::default() });
    let plan = off
        .plan(&OpDesc { batch: 1, z: 64, k: 64, variant: Variant::parse("w4a8").unwrap() })
        .unwrap();
    assert_eq!(plan.kernel_name(), "ruy-w8a8");
}

#[test]
fn widened_fallback_is_numerically_consistent() {
    // sub-byte data on the Ruy fallback (batch path) must equal the
    // FullPack GEMV on the same data — the §4.6 split cannot change
    // results, only speed
    let v = Variant::parse("w4a8").unwrap();
    let (z, k) = (32usize, 96usize);
    let w = rngvals(v.w, z * k, 5);
    let a = rngvals(v.a, k, 6);
    let gemv_plan = PlanBuilder::new(LayerShape { z, k, batch: 1 }, v).build().unwrap();
    let ruy_plan = PlanBuilder::new(LayerShape { z, k, batch: 2 }, v).build().unwrap();
    assert_eq!(ruy_plan.kernel_name(), "ruy-w8a8");
    let mut out_fp = vec![0i32; z];
    let wf = gemv_plan.prepare_weights(&w).unwrap();
    gemv_plan.execute(&wf, &a, &mut out_fp).unwrap();
    let wr = ruy_plan.prepare_weights(&w).unwrap();
    let mut out_ruy = vec![0i32; z];
    ruy_plan.execute(&wr, &a, &mut out_ruy).unwrap();
    assert_eq!(out_fp, out_ruy);
}

//! Registry conformance suite: every registered kernel, on every paper
//! variant it supports (plus W8A8), across unaligned depths, must match
//! the scalar oracle when driven through the `Plan` API — the contract
//! that makes "add a backend" safe as one registry entry.
//!
//! Also proves the Router→Plan redesign is behavior-preserving: the new
//! plan selection reproduces the old two-way path decisions (FullPack
//! GEMV vs Ruy GEMM) for the paper's §4.6 policy.

use fullpack::coordinator::{OpDesc, Router, RouterConfig};
use fullpack::kernels::testutil::{oracle_gemv, pad_rows, rngvals};
use fullpack::kernels::{
    ActVec, GemvKernel, KernelRegistry, LayerShape, PlanBuilder, RowParallel, SelectPolicy,
};
use fullpack::pack::Variant;

const DEPTHS: [usize; 4] = [1, 17, 127, 129];

/// SWAR-tier depth sweep: chunk-aligned and unaligned, below/above one
/// packed group, plus the `w8a8` scalar-tail depths (`k % 8 != 0`).
const SWAR_DEPTHS: [usize; 9] = [1, 7, 8, 9, 63, 64, 65, 127, 129];

fn variants_under_test() -> Vec<Variant> {
    let mut v = Variant::PAPER_VARIANTS.to_vec();
    v.push(Variant::parse("w8a8").unwrap());
    v
}

/// Run `kernel` on a `z × k` layer of `variant` data through a Plan and
/// compare with the oracle.  `exact` distinguishes integer kernels from
/// the f32 stand-ins (exact only inside f32's 2^24 integer range — the
/// small shapes here stay inside it).
fn check(kernel: &str, variant: Variant, z: usize, k: usize, seed: u64) {
    let plan = PlanBuilder::new(LayerShape { z, k, batch: 1 }, variant)
        .policy(SelectPolicy::Explicit(kernel.to_string()))
        .build()
        .unwrap_or_else(|e| panic!("{kernel} {variant} k={k}: {e}"));
    let w = rngvals(variant.w, z * k, seed);
    let a = rngvals(variant.a, k, seed + 1);
    let weights = plan.prepare_weights(&w).expect("prepare");
    let mut out = vec![0i32; z];
    plan.execute(&weights, &a, &mut out).expect("execute");
    let kp = weights.k_padded();
    let wp = pad_rows(&w, z, k, kp);
    let mut ap = a.clone();
    ap.resize(kp, 0);
    assert_eq!(out, oracle_gemv(&wp, &ap, z, kp), "{kernel} {variant} z={z} k={k}");
}

#[test]
fn every_kernel_matches_oracle_on_supported_variants() {
    let reg = KernelRegistry::global();
    let mut covered = 0usize;
    for kernel in reg.iter() {
        for variant in variants_under_test() {
            if !kernel.supports(variant) {
                continue;
            }
            for (i, k) in DEPTHS.iter().enumerate() {
                check(kernel.name(), variant, 8, *k, 1000 + i as u64);
            }
            covered += 1;
        }
    }
    // floor: 9 fullpack + 4 swar + 3 naive + 3 ulppack + (4 i8 + 3 f32)
    // × w8a8; new backends only grow the count
    assert!(covered >= 26, "kernel×variant coverage shrank: {covered}");
}

/// Every `*-swar` backend is bit-exact with the scalar oracle across
/// its supported variants at chunk-aligned and unaligned depths,
/// including the `w8a8` tail-fallback path (`k % 8 != 0`).
#[test]
fn swar_backends_match_oracle_at_unaligned_depths() {
    let reg = KernelRegistry::global();
    let mut found = 0usize;
    for kernel in reg.iter().filter(|k| k.name().ends_with("-swar")) {
        for variant in variants_under_test() {
            if !kernel.supports(variant) {
                continue;
            }
            for (i, k) in SWAR_DEPTHS.iter().enumerate() {
                check(kernel.name(), variant, 8, *k, 5000 + i as u64);
            }
            found += 1;
        }
    }
    assert!(found >= 4, "SWAR backend coverage shrank: {found}");
}

/// The SWAR tier agrees bit-for-bit with its staged scalar sibling (not
/// just the oracle) — the two tiers are interchangeable per plan.
#[test]
fn swar_and_scalar_tiers_agree_exactly() {
    for (scalar, swar, vname) in [
        ("fullpack-w4a8", "fullpack-w4a8-swar", "w4a8"),
        ("fullpack-w2a8", "fullpack-w2a8-swar", "w2a8"),
        ("fullpack-w1a8", "fullpack-w1a8-swar", "w1a8"),
        ("ruy-w8a8", "fullpack-w8a8-swar", "w8a8"),
    ] {
        let v = Variant::parse(vname).unwrap();
        for k in [9usize, 64, 129] {
            let z = 16;
            let w = rngvals(v.w, z * k, 61 + k as u64);
            let a = rngvals(v.a, k, 62 + k as u64);
            let run = |name: &str| -> Vec<i32> {
                let plan = PlanBuilder::new(LayerShape { z, k, batch: 1 }, v)
                    .policy(SelectPolicy::Explicit(name.to_string()))
                    .build()
                    .unwrap();
                let wts = plan.prepare_weights(&w).unwrap();
                let mut out = vec![0i32; z];
                plan.execute(&wts, &a, &mut out).unwrap();
                out
            };
            assert_eq!(run(scalar), run(swar), "{vname} k={k}");
        }
    }
}

/// Every real-ISA backend (`fullpack-*-avx2` / `-neon`) is bit-exact
/// with the naive oracle **and** with its scalar and SWAR siblings
/// across the full unaligned-depth grid — the three tiers share one
/// packed layout and must be interchangeable per plan.  The roster is
/// detection-gated, so backends this host cannot execute are simply
/// absent and auto-skip (visibly, so CI logs show the coverage).
#[test]
fn isa_backends_match_oracle_and_siblings_across_depths() {
    use fullpack::kernels::{isa_kernel_name, IsaKind, ISA_VARIANTS};
    let reg = KernelRegistry::global();
    let mut covered = 0usize;
    for kind in [IsaKind::Avx2, IsaKind::Neon] {
        for v in ISA_VARIANTS {
            let name = isa_kernel_name(v, kind).unwrap();
            if reg.get(name).is_none() {
                eprintln!("SKIP {name}: not executable on this host (never registered)");
                continue;
            }
            // vs the naive oracle, across the SWAR-tier depth grid
            for (i, k) in SWAR_DEPTHS.iter().enumerate() {
                check(name, v, 8, *k, 9000 + i as u64);
            }
            // vs the scalar and SWAR siblings on the same data
            let scalar = format!("fullpack-{}", v.name());
            let swar = format!("fullpack-{}-swar", v.name());
            for k in SWAR_DEPTHS {
                let z = 16;
                let w = rngvals(v.w, z * k, 9100 + k as u64);
                let a = rngvals(v.a, k, 9200 + k as u64);
                let run = |kernel: &str| -> Vec<i32> {
                    let plan = PlanBuilder::new(LayerShape { z, k, batch: 1 }, v)
                        .policy(SelectPolicy::Explicit(kernel.to_string()))
                        .build()
                        .unwrap();
                    let wts = plan.prepare_weights(&w).unwrap();
                    let mut out = vec![0i32; z];
                    plan.execute(&wts, &a, &mut out).unwrap();
                    out
                };
                let isa_out = run(name);
                assert_eq!(isa_out, run(&scalar), "{name} vs {scalar} k={k}");
                assert_eq!(isa_out, run(&swar), "{name} vs {swar} k={k}");
            }
            covered += 1;
        }
    }
    eprintln!("isa conformance: {covered} ISA backend(s) executable on this host");
}

/// `RowParallel` composes over the ISA tier exactly like the SWAR tier:
/// sharded execution is bit-identical to serial (skips visibly when the
/// host registers no ISA backend).
#[test]
fn row_parallel_composes_over_the_isa_tier() {
    use fullpack::kernels::{isa_kernel_name, ISA_VARIANTS};
    let reg = KernelRegistry::global();
    let support = fullpack::kernels::isa::detected();
    let Some(kind) = support.kinds().first().copied() else {
        eprintln!("SKIP row_parallel_composes_over_the_isa_tier: no ISA tier on this host");
        return;
    };
    let v = ISA_VARIANTS[0];
    let base = reg.get(isa_kernel_name(v, kind).unwrap()).unwrap();
    let (z, k) = (1024usize, 160usize);
    let w = rngvals(v.w, z * k, 83);
    let mut a = rngvals(v.a, k, 84);
    a.resize(v.padded_depth(k), 0);
    let wts = base.prepare(&w, z, k).unwrap();
    let mut serial = vec![0i32; z];
    base.gemv_at(&wts, ActVec::I8(&a), &mut serial, 0).unwrap();
    for threads in [2usize, 4] {
        let par = RowParallel::new(base.clone(), threads);
        let mut out = vec![0i32; z];
        par.gemv_at(&wts, ActVec::I8(&a), &mut out, 0).unwrap();
        assert_eq!(out, serial, "threads={threads}");
    }
    let kp = v.padded_depth(k);
    let wp = pad_rows(&w, z, k, kp);
    assert_eq!(serial, oracle_gemv(&wp, &a, z, kp));
}

/// `RowParallel` composes over the SWAR tier: sharded execution is
/// bit-identical to the serial call and to the oracle.
#[test]
fn row_parallel_composes_over_swar() {
    let reg = KernelRegistry::global();
    let base = reg.get("fullpack-w4a8-swar").unwrap();
    let (z, k) = (1024usize, 160usize);
    let v = Variant::parse("w4a8").unwrap();
    let w = rngvals(v.w, z * k, 81);
    let mut a = rngvals(v.a, k, 82);
    a.resize(v.padded_depth(k), 0);
    let wts = base.prepare(&w, z, k).unwrap();
    let mut serial = vec![0i32; z];
    base.gemv_at(&wts, ActVec::I8(&a), &mut serial, 0).unwrap();
    for threads in [2usize, 4] {
        let par = RowParallel::new(base.clone(), threads);
        let mut out = vec![0i32; z];
        par.gemv_at(&wts, ActVec::I8(&a), &mut out, 0).unwrap();
        assert_eq!(out, serial, "threads={threads}");
    }
    let kp = v.padded_depth(k);
    let wp = pad_rows(&w, z, k, kp);
    assert_eq!(serial, oracle_gemv(&wp, &a, z, kp));
}

/// The serving router's `prefer_swar` knob routes deep GEMV ops to the
/// tier while batched/8-bit ops keep the baseline path.
#[test]
fn router_prefer_swar_routes_to_the_tier() {
    let r = Router::new(RouterConfig { prefer_swar: true, ..Default::default() });
    let op = |batch: usize, v: &str| OpDesc {
        batch,
        z: 2048,
        k: 2048,
        variant: Variant::parse(v).unwrap(),
    };
    assert_eq!(r.plan(&op(1, "w1a8")).unwrap().kernel_name(), "fullpack-w1a8-swar");
    assert_eq!(r.plan(&op(16, "w1a8")).unwrap().kernel_name(), "ruy-like-w8a8-gemm");
    assert_eq!(r.plan(&op(1, "w4a4")).unwrap().kernel_name(), "fullpack-w4a4");
    let (gemv, gemm) = r.counts();
    assert_eq!((gemv, gemm), (2, 1));
}

#[test]
fn every_paper_variant_has_a_native_kernel() {
    let reg = KernelRegistry::global();
    for v in Variant::PAPER_VARIANTS {
        let names: Vec<_> = reg.supporting(v).iter().map(|k| k.name()).collect();
        assert!(
            names.iter().any(|n| n.starts_with("fullpack-")),
            "{v}: no FullPack kernel ({names:?})"
        );
    }
}

#[test]
fn larger_shapes_and_row_parallel_agree() {
    // deeper/wider layers + the plan's thread budget: sharded execution
    // must stay bit-identical across every paper variant
    for v in Variant::PAPER_VARIANTS {
        let (z, k) = (1024usize, 160usize);
        let plan = PlanBuilder::new(LayerShape { z, k, batch: 1 }, v)
            .threads(4)
            .build()
            .unwrap();
        assert!(plan.is_fullpack(), "{v}");
        let w = rngvals(v.w, z * k, 77);
        let a = rngvals(v.a, k, 78);
        let wts = plan.prepare_weights(&w).unwrap();
        let mut out = vec![0i32; z];
        plan.execute(&wts, &a, &mut out).unwrap();
        let kp = wts.k_padded();
        let wp = pad_rows(&w, z, k, kp);
        let mut ap = a.clone();
        ap.resize(kp, 0);
        assert_eq!(out, oracle_gemv(&wp, &ap, z, kp), "{v}");
    }
}

/// The old `Router::route` truth table (paper §4.6), replayed against
/// the Plan-emitting router: the old FullPack-GEMV path ⇔ a
/// `fullpack-*` kernel; the old Ruy-GEMM path ⇔ `ruy-w8a8` for
/// single-column ops and the first-class `ruy-like-w8a8-gemm` backend
/// for batched ones (same protocol, same numbers — the widened
/// consistency test below pins that).
#[test]
fn router_plans_reproduce_old_path_decisions() {
    let cases: &[(usize, &str, bool)] = &[
        // (batch, variant, expected old Path == FullPackGemv)
        (1, "w4a8", true),   // single-batch sub-byte LSTM step
        (1, "w2a2", true),
        (1, "w1a1", true),
        (16, "w4a8", false), // batch-16 FC → Ruy GEMM
        (2, "w1a8", false),
        (1, "w8a8", false),  // 8-bit always on the baseline
        (16, "w8a8", false),
    ];
    let r = Router::new(RouterConfig::default());
    for &(batch, vname, fullpack) in cases {
        let plan = r
            .plan(&OpDesc { batch, z: 2048, k: 2048, variant: Variant::parse(vname).unwrap() })
            .unwrap();
        if fullpack {
            assert_eq!(plan.kernel_name(), format!("fullpack-{vname}"), "batch={batch}");
        } else {
            let expect = if batch > 1 { "ruy-like-w8a8-gemm" } else { "ruy-w8a8" };
            assert_eq!(plan.kernel_name(), expect, "{vname} batch={batch}");
            assert_eq!(plan.exec_variant, Variant::parse("w8a8").unwrap());
        }
    }
    let (gemv, gemm) = r.counts();
    assert_eq!((gemv, gemm), (3, 4));

    // the ablation switch forces the baseline path, as the old router did
    let off = Router::new(RouterConfig { disable_fullpack: true, ..Default::default() });
    let plan = off
        .plan(&OpDesc { batch: 1, z: 64, k: 64, variant: Variant::parse("w4a8").unwrap() })
        .unwrap();
    assert_eq!(plan.kernel_name(), "ruy-w8a8");
}

#[test]
fn widened_fallback_is_numerically_consistent() {
    // sub-byte data on the Ruy fallback (batch path) must equal the
    // FullPack GEMV on the same data — the §4.6 split cannot change
    // results, only speed
    let v = Variant::parse("w4a8").unwrap();
    let (z, k) = (32usize, 96usize);
    let w = rngvals(v.w, z * k, 5);
    let a = rngvals(v.a, k, 6);
    let gemv_plan = PlanBuilder::new(LayerShape { z, k, batch: 1 }, v).build().unwrap();
    let ruy_plan = PlanBuilder::new(LayerShape { z, k, batch: 2 }, v).build().unwrap();
    assert_eq!(ruy_plan.kernel_name(), "ruy-like-w8a8-gemm");
    let mut out_fp = vec![0i32; z];
    let wf = gemv_plan.prepare_weights(&w).unwrap();
    gemv_plan.execute(&wf, &a, &mut out_fp).unwrap();
    let wr = ruy_plan.prepare_weights(&w).unwrap();
    let mut out_ruy = vec![0i32; z];
    ruy_plan.execute(&wr, &a, &mut out_ruy).unwrap();
    assert_eq!(out_fp, out_ruy);
}

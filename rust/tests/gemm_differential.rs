//! Differential suite across the GEMV/GEMM boundary (DESIGN.md §9):
//! for every registered GEMM backend, on every bit-width it can run
//! (natively or widened), across batch sizes and unaligned depths,
//!
//!   `GemmKernel::gemm  ≡  repeated GemvKernel::gemv_at  ≡  naive oracle`
//!
//! — the contract that lets the router promote a flushed multi-request
//! batch onto one GEMM call without changing a single output bit.
//! Also pins the shape-error rejection paths and the `k_padded` tail
//! handling of `gemm_fullpack`.

use fullpack::kernels::fullpack_gemm::gemm_fullpack_dyn;
use fullpack::kernels::registry::fullpack_kernel_name;
use fullpack::kernels::testutil::{oracle_gemv, rngvals};
use fullpack::kernels::{
    ActVec, GemmKernel, GemvKernel, KernelRegistry, LayerShape, PlanBuilder, RowParallelGemm,
};
use fullpack::pack::{BitWidth, PackedMatrix, Variant};
use fullpack::util::proptest_lite::{run_prop, Gen};
use std::sync::Arc;

/// The bit-widths of the differential grid (weights; activations int8).
const WIDTHS: [BitWidth; 4] = [BitWidth::B1, BitWidth::B2, BitWidth::B4, BitWidth::B8];
/// Batch sizes: singleton, the promotion threshold, odd, a full flush,
/// and one past a full flush.
const BATCHES: [usize; 5] = [1, 2, 3, 16, 17];
/// Depths: below/at/above the 8-byte SWAR chunk and the packed group,
/// plus unaligned serving depths — every one exercises a distinct
/// padding/tail configuration.
const DEPTHS: [usize; 9] = [1, 7, 8, 9, 63, 64, 65, 127, 129];

const W8A8: Variant = Variant::new(BitWidth::B8, BitWidth::B8);

/// The variant a backend executes for data quantized as `v`: native,
/// or widened onto int8 (value-preserving — sub-byte values pass
/// through the int8 layout losslessly), or `None` if neither.
fn exec_variant(g: &Arc<dyn GemmKernel>, v: Variant) -> Option<Variant> {
    if g.supports(v) {
        Some(v)
    } else if g.supports(W8A8) {
        Some(W8A8)
    } else {
        None
    }
}

/// The same-layout GEMV reference for an exec variant: the FullPack
/// GEMV kernel for sub-byte data, Ruy for int8.
fn gemv_reference(ev: Variant) -> &'static Arc<dyn GemvKernel> {
    let name = if ev.w.is_sub_byte() { fullpack_kernel_name(ev) } else { "ruy-w8a8" };
    KernelRegistry::global().get(name).expect("reference kernel registered")
}

/// Scalar int32 ground truth over the *logical* operands — padding
/// contributes zero in every layout, so depth-`k` logical math
/// (`testutil::oracle_gemv`, which truncates `col` to `k`) is the
/// oracle for all of them.
fn logical_oracle(w: &[i8], col: &[i8], z: usize, k: usize) -> Vec<i32> {
    oracle_gemv(w, &col[..k.min(col.len())], z, k)
}

/// One differential cell: backend × width × batch × depth.
fn check_cell(g: &Arc<dyn GemmKernel>, bits: BitWidth, z: usize, k: usize, batch: usize, seed: u64) {
    let v = Variant::new(bits, BitWidth::B8);
    let Some(ev) = exec_variant(g, v) else { return };
    let w = rngvals(bits, z * k, seed);
    let wts = g.prepare(&w, z, k).expect("prepare");
    let kp = wts.k_padded();
    assert!(kp >= k, "{}: k_padded {kp} < k {k}", g.name());
    let cols: Vec<Vec<i8>> = (0..batch)
        .map(|c| {
            let mut col = rngvals(BitWidth::B8, k, seed + 1 + c as u64);
            col.resize(kp, 0);
            col
        })
        .collect();
    let col_refs: Vec<&[i8]> = cols.iter().map(|c| c.as_slice()).collect();
    let mut out = vec![0i32; z * batch];
    g.gemm(&wts, &col_refs, &mut out).expect("gemm");

    // repeated GEMV on the reference kernel's own layout
    let gemv = gemv_reference(ev);
    let gw = gemv.prepare(&w, z, k).expect("gemv prepare");
    let gkp = gw.k_padded();
    for (c, col) in cols.iter().enumerate() {
        let oracle = logical_oracle(&w, col, z, k);
        let got = &out[c * z..(c + 1) * z];
        assert_eq!(
            got,
            oracle.as_slice(),
            "{} {bits:?} z={z} k={k} batch={batch} col {c}: gemm vs oracle",
            g.name()
        );
        let mut acol = col.clone();
        acol.resize(gkp.max(col.len()), 0);
        let mut one = vec![0i32; z];
        gemv.gemv_at(&gw, ActVec::I8(&acol), &mut one, 0).expect("gemv");
        assert_eq!(
            one.as_slice(),
            got,
            "{} {bits:?} z={z} k={k} batch={batch} col {c}: repeated gemv vs gemm",
            g.name()
        );
    }
}

#[test]
fn every_gemm_backend_matches_repeated_gemv_and_oracle() {
    let reg = KernelRegistry::global();
    assert!(reg.gemm_len() >= 5, "GEMM roster shrank: {}", reg.gemm_len());
    let mut covered = 0usize;
    for g in reg.gemm_iter() {
        for bits in WIDTHS {
            let v = Variant::new(bits, BitWidth::B8);
            if exec_variant(g, v).is_none() {
                continue;
            }
            for (bi, &batch) in BATCHES.iter().enumerate() {
                for (ki, &k) in DEPTHS.iter().enumerate() {
                    check_cell(g, bits, 8, k, batch, 9000 + (bi * 100 + ki) as u64);
                }
            }
            covered += 1;
        }
    }
    // floor: 3 fullpack-gemm × 1 native width + ruy-like × 4 widths
    // (native + widened) + oracle × 4 native widths
    assert!(covered >= 11, "backend×width coverage shrank: {covered}");
}

#[test]
fn empty_batch_is_a_no_op_for_every_backend() {
    let reg = KernelRegistry::global();
    for g in reg.gemm_iter() {
        for bits in WIDTHS {
            let v = Variant::new(bits, BitWidth::B8);
            if exec_variant(g, v).is_none() {
                continue;
            }
            let w = rngvals(bits, 8 * 64, 3);
            let wts = g.prepare(&w, 8, 64).unwrap();
            let mut out = vec![];
            g.gemm(&wts, &[], &mut out).unwrap();
        }
    }
}

#[test]
fn gemm_fullpack_rejects_bad_shapes() {
    let w = rngvals(BitWidth::B4, 8 * 32, 1);
    let wp = PackedMatrix::from_i8(&w, 8, 32, BitWidth::B4).unwrap();
    let a = vec![0i8; 32];
    // wrong output length
    let mut bad = vec![0i32; 7];
    assert!(gemm_fullpack_dyn(&wp, &[&a], &mut bad).is_err());
    let mut bad2 = vec![0i32; 9];
    assert!(gemm_fullpack_dyn(&wp, &[&a], &mut bad2).is_err());
    // column shorter than the padded depth
    let short = vec![0i8; 31];
    let mut out = vec![0i32; 8];
    assert!(gemm_fullpack_dyn(&wp, &[&short], &mut out).is_err());
    // only one bad column in a batch still rejects
    let mut out2 = vec![0i32; 16];
    assert!(gemm_fullpack_dyn(&wp, &[&a, &short], &mut out2).is_err());
    // 8-bit weights are not a FullPack GEMM case
    let w8 = PackedMatrix::from_i8(&vec![0i8; 8 * 32], 8, 32, BitWidth::B8).unwrap();
    assert!(gemm_fullpack_dyn(&w8, &[&a], &mut out).is_err());
}

#[test]
fn gemm_backends_reject_foreign_layouts() {
    let reg = KernelRegistry::global();
    // the oracle's unpacked layout is foreign to every other backend
    let oracle = reg.get_gemm("naive-oracle-gemm").unwrap();
    let w = rngvals(BitWidth::B4, 8 * 64, 5);
    let foreign = oracle.prepare(&w, 8, 64).unwrap();
    let col = vec![0i8; 64];
    let mut out = vec![0i32; 8];
    for name in ["fullpack-w4a8-gemm", "ruy-like-w8a8-gemm"] {
        let g = reg.get_gemm(name).unwrap();
        assert!(g.gemm(&foreign, &[col.as_slice()], &mut out).is_err(), "{name}");
    }
    // and the packed sub-byte layout is foreign to the int8 rival
    let fp = reg.get_gemm("fullpack-w4a8-gemm").unwrap();
    let packed = fp.prepare(&w, 8, 64).unwrap();
    let ruy = reg.get_gemm("ruy-like-w8a8-gemm").unwrap();
    assert!(ruy.gemm(&packed, &[col.as_slice()], &mut out).is_err());
}

#[test]
fn k_padded_tail_is_zero_neutral() {
    // for unaligned depths the packed tail is zero-filled; columns
    // padded with *nonzero* garbage past the logical depth must still
    // produce the logical result when the weight tail is zero
    let reg = KernelRegistry::global();
    for (vname, bits) in [("w4a8", BitWidth::B4), ("w2a8", BitWidth::B2), ("w1a8", BitWidth::B1)] {
        let g = reg.get_gemm(&format!("fullpack-{vname}-gemm")).unwrap();
        let (z, k) = (4usize, 65usize);
        let w = rngvals(bits, z * k, 17);
        let wts = g.prepare(&w, z, k).unwrap();
        let kp = wts.k_padded();
        assert!(kp > k, "{vname}: depth 65 must pad");
        let mut col = rngvals(BitWidth::B8, k, 18);
        col.resize(kp, 0);
        let mut poisoned = col.clone();
        for x in poisoned[k..].iter_mut() {
            *x = 77; // garbage in the padded region
        }
        let mut clean_out = vec![0i32; z];
        let mut poisoned_out = vec![0i32; z];
        g.gemm(&wts, &[col.as_slice()], &mut clean_out).unwrap();
        g.gemm(&wts, &[poisoned.as_slice()], &mut poisoned_out).unwrap();
        assert_eq!(clean_out, logical_oracle(&w, &col, z, k), "{vname}");
        assert_eq!(clean_out, poisoned_out, "{vname}: weight tail not zero-neutral");
    }
}

#[test]
fn router_promoted_plans_are_differentially_correct() {
    // the end-to-end path the engine takes: a prefer_gemm plan for a
    // flushed batch, executed through Plan::execute_batch, must equal
    // the per-column logical oracle
    for vname in ["w4a8", "w2a8", "w1a8"] {
        let v = Variant::parse(vname).unwrap();
        let (z, k, batch) = (16usize, 129usize, 5usize);
        let plan = PlanBuilder::new(LayerShape { z, k, batch }, v)
            .prefer_gemm(true)
            .build()
            .unwrap();
        assert_eq!(plan.kernel_name(), format!("fullpack-{vname}-gemm"));
        let w = rngvals(v.w, z * k, 23);
        let a = rngvals(BitWidth::B8, batch * k, 24);
        let wts = plan.prepare_weights(&w).unwrap();
        let mut out = vec![0i32; batch * z];
        plan.execute_batch(&wts, &a, batch, &mut out).unwrap();
        for b in 0..batch {
            let col = &a[b * k..(b + 1) * k];
            assert_eq!(
                &out[b * z..(b + 1) * z],
                logical_oracle(&w, col, z, k).as_slice(),
                "{vname} col {b}"
            );
        }
    }
}

#[test]
fn tile_parallel_gemm_equals_serial_for_every_backend() {
    // the RowParallelGemm decorator (→ GemmKernel::gemm_at row tiles)
    // must be bit-identical to the serial batched call on every
    // registered GEMM backend, at a row count large enough to spawn
    // real shards and a depth with a padded tail
    let reg = KernelRegistry::global();
    let (z, k, batch) = (1024usize, 65usize, 3usize);
    for g in reg.gemm_iter() {
        let bits = WIDTHS
            .into_iter()
            .find(|&b| exec_variant(g, Variant::new(b, BitWidth::B8)).is_some());
        let Some(bits) = bits else { continue };
        let w = rngvals(bits, z * k, 211);
        let wts = g.prepare(&w, z, k).unwrap();
        let kp = wts.k_padded();
        let cols: Vec<Vec<i8>> = (0..batch)
            .map(|c| {
                let mut col = rngvals(BitWidth::B8, k, 212 + c as u64);
                col.resize(kp, 0);
                col
            })
            .collect();
        let refs: Vec<&[i8]> = cols.iter().map(|c| c.as_slice()).collect();
        let mut serial = vec![0i32; z * batch];
        g.gemm(&wts, &refs, &mut serial).unwrap();
        for (c, col) in cols.iter().enumerate() {
            assert_eq!(
                &serial[c * z..(c + 1) * z],
                logical_oracle(&w, col, z, k).as_slice(),
                "{} col {c}: serial vs oracle",
                g.name()
            );
        }
        for threads in [2usize, 4] {
            let par = RowParallelGemm::new(g.clone(), threads);
            let mut out = vec![0i32; z * batch];
            par.gemm(&wts, &refs, &mut out).unwrap();
            assert_eq!(out, serial, "{} threads={threads}", g.name());
        }
    }
}

#[test]
fn prop_differential_random_shapes() {
    // randomized extension of the grid: arbitrary (z, k, batch) cells
    // over a random backend × width, against the logical oracle
    let reg = KernelRegistry::global();
    let names = reg.gemm_names();
    run_prop(60, |g: &mut Gen| {
        let name = *g.pick(&names);
        let backend = reg.get_gemm(name).unwrap();
        let bits = *g.pick(&WIDTHS);
        let v = Variant::new(bits, BitWidth::B8);
        if exec_variant(backend, v).is_none() {
            return true; // cell not applicable
        }
        let z = g.usize_in(1, 16);
        let k = g.usize_in(1, 200);
        let batch = g.usize_in(1, 6);
        let seed = g.next_u64() % 10_000;
        check_cell(backend, bits, z, k, batch, seed);
        true
    });
}

//! Model-store battery (DESIGN.md §14): the multi-tenant residency /
//! hot-swap contract under concurrent load.
//!
//! - **eviction storm** — N producer threads against a 100-model
//!   synthetic zoo on a budget that fits ~8 resident models: every
//!   accepted request replies exactly once, every cold admission is a
//!   typed shed whose retry lands warm, the pinned model is never
//!   evicted, and the store's load/eviction counters reconcile with
//!   [`Metrics::model_store_counts`] and with the clients' tallies;
//! - **hot-swap atomicity** — swapping under producer load yields only
//!   whole versions: every reply bit-matches the v1 or the v2
//!   reference forward, never a torn mix, and the version counter and
//!   swap metrics account for exactly one flip;
//! - **in-flight drain** — a dispatch whose guard was taken before the
//!   swap finishes bit-exact on the v1 weights it captured, while the
//!   next dispatch after the swap serves v2.

use std::sync::atomic::Ordering::Relaxed;
use std::sync::Arc;
use std::time::Duration;

use fullpack::coordinator::request::{LayerTiming, OpDesc};
use fullpack::coordinator::{
    Engine, EngineConfig, RouterConfig, SchedulerConfig, ShedReason, StoreConfig, SubmitError,
};
use fullpack::models::{
    synthetic_roster, CompiledModel, Model, ModelBuilder, ModelRegistry, ModelSize,
};
use fullpack::pack::Variant;
use fullpack::util::rng::SplitMix64;

const REPLY_BOUND: Duration = Duration::from_secs(30);

fn v(s: &str) -> Variant {
    Variant::parse(s).unwrap()
}

fn tiny_compiled(name: &str, seed: u64) -> CompiledModel {
    let g = ModelRegistry::global().build(name, ModelSize::Tiny, v("w4a8"), seed).unwrap();
    CompiledModel::compile(g).unwrap()
}

#[test]
fn eviction_storm_exactly_once_and_counters_reconcile() {
    const ZOO_N: usize = 100;
    const PRODUCERS: usize = 4;
    const PER_PRODUCER: usize = 40;
    let roster = synthetic_roster(ZOO_N, ModelSize::Tiny, v("w4a8"), 7);
    // topology cycle is deepspeech/mlp/keyword-spotter: byte size and
    // input length depend on topology only, so probe each base once
    let probes: Vec<CompiledModel> =
        (0..3).map(|i| CompiledModel::compile(roster[i].1.clone()).unwrap()).collect();
    let sizes: Vec<usize> = probes.iter().map(|m| m.resident_bytes()).collect();
    let lens: Vec<usize> = probes.iter().map(|m| m.input_len()).collect();
    // budget: exactly the first eight roster models resident at once
    let budget: usize = (0..8).map(|i| sizes[i % 3]).sum();
    assert!(sizes.iter().all(|&b| b > 0), "tiny models must charge bytes");

    let e = Engine::new(EngineConfig {
        workers: 2,
        sched: SchedulerConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            max_queue: 64,
            slo: Duration::from_secs(5),
            ..SchedulerConfig::default()
        },
        router: RouterConfig::default(),
        store: StoreConfig { budget_bytes: Some(budget as u64) },
    });
    let names: Vec<String> = roster.iter().map(|(n, _)| n.clone()).collect();
    for (i, (name, graph)) in roster.into_iter().enumerate() {
        let builder: ModelBuilder = Box::new(move || {
            CompiledModel::compile(graph.clone())
                .map(|m| Arc::new(m) as Arc<dyn Model>)
                .map_err(|e| e.to_string())
        });
        e.register_model_lazy(&name, sizes[i % 3], builder).unwrap();
    }
    e.pin_model(&names[0]).unwrap(); // eager load, evict-exempt

    let e = Arc::new(e);
    let mut handles = Vec::new();
    for p in 0..PRODUCERS {
        let e = Arc::clone(&e);
        let names = names.clone();
        let lens = lens.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = SplitMix64::stream(17, p as u64);
            let (mut attempts, mut cold, mut other_shed) = (0u64, 0u64, 0u64);
            let mut rxs = Vec::new();
            for _ in 0..PER_PRODUCER {
                let idx = rng.usize_in(0, ZOO_N - 1);
                let frames = vec![0.25f32; lens[idx % 3]];
                let mut tries = 0;
                loop {
                    attempts += 1;
                    tries += 1;
                    match e.try_submit(&names[idx], frames.clone()) {
                        Ok(rx) => {
                            rxs.push(rx);
                            break;
                        }
                        Err(SubmitError::Rejected(r)) if r.reason == ShedReason::ColdModel => {
                            // the shed itself performed the load: the
                            // retry is warm unless concurrent loads
                            // evicted it again in the window
                            cold += 1;
                            assert!(r.retry_after_us >= 1, "cold shed without retry hint");
                            assert_eq!(r.depth, 0, "cold sheds happen before enqueue");
                            assert!(tries <= 100, "cold-retry livelock on {:?}", names[idx]);
                        }
                        Err(SubmitError::Rejected(_)) => {
                            other_shed += 1;
                            break;
                        }
                        Err(err) => panic!("roster model refused: {err}"),
                    }
                }
            }
            let mut ids = Vec::new();
            for rx in rxs {
                let r = rx
                    .recv_timeout(REPLY_BOUND)
                    .expect("accepted requests always reply")
                    .expect("well-formed requests succeed");
                assert!(r.logits.iter().all(|x| x.is_finite()));
                ids.push(r.id);
            }
            (attempts, cold, other_shed, ids)
        }));
    }
    let (mut attempts, mut cold, mut other_shed) = (0u64, 0u64, 0u64);
    let mut all_ids = Vec::new();
    for h in handles {
        let (a, c, o, ids) = h.join().unwrap();
        attempts += a;
        cold += c;
        other_shed += o;
        all_ids.extend(ids);
    }
    // exactly once: every accepted request answered, no id twice
    let accepted = attempts - cold - other_shed;
    all_ids.sort_unstable();
    all_ids.dedup();
    assert_eq!(all_ids.len() as u64, accepted, "duplicate or lost replies");
    assert!(cold > 0, "a 100-model zoo on an 8-model budget must shed cold");

    let m = e.metrics();
    assert_eq!(m.requests.load(Relaxed), attempts, "every attempt counted");
    assert_eq!(m.completed.load(Relaxed), accepted);
    assert_eq!(m.errors.load(Relaxed), 0);
    let (qf, ob, cm) = m.shed_counts();
    assert_eq!(cm, cold, "typed cold sheds reconcile with client tallies");
    assert_eq!(qf + ob, other_shed);

    // store counters reconcile with metrics, and the budget held
    let s = e.store().stats();
    assert_eq!(s.models, ZOO_N);
    assert!(s.evictions > 0, "the storm never hit the budget");
    assert!(s.loads >= s.evictions, "can't evict what was never loaded");
    let (loads, evictions, swaps) = m.model_store_counts();
    assert_eq!((s.loads, s.evictions, 0), (loads, evictions, swaps));

    // the pinned model rode out the whole storm resident
    let pinned = e.store().entry_stats(&names[0]).unwrap();
    assert!(pinned.pinned && pinned.resident);
    assert_eq!(pinned.evictions, 0, "pinned models are never evicted");

    let e = Arc::try_unwrap(e).ok().expect("all producers joined");
    let store = Arc::clone(e.store());
    e.shutdown();
    // drained: no dispatch holds remain, and the modeled budget holds
    let s = store.stats();
    assert!(
        s.resident_bytes <= budget,
        "post-drain residency {} exceeds budget {}",
        s.resident_bytes,
        budget
    );
    assert!(store.per_entry().iter().all(|r| r.in_flight == 0));
}

#[test]
fn hot_swap_under_load_yields_only_whole_versions() {
    const PRODUCERS: usize = 3;
    const PER_PRODUCER: usize = 30;
    let e = Engine::new(EngineConfig {
        workers: 2,
        sched: SchedulerConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            max_queue: 256,
            slo: Duration::from_secs(5),
            ..SchedulerConfig::default()
        },
        router: RouterConfig::default(),
        store: StoreConfig::default(),
    });
    e.register_model("m", tiny_compiled("deepspeech", 1)).unwrap();
    let len = e.model("m").unwrap().input_len();
    let input = vec![0.1f32; len];
    let ref1 = e.infer("m", input.clone()).unwrap().logits;

    let e = Arc::new(e);
    let mut handles = Vec::new();
    for _ in 0..PRODUCERS {
        let e = Arc::clone(&e);
        let input = input.clone();
        handles.push(std::thread::spawn(move || {
            let mut replies = Vec::new();
            let rxs: Vec<_> = (0..PER_PRODUCER)
                .map(|_| e.try_submit("m", input.clone()).expect("queue sized for the load"))
                .collect();
            for rx in rxs {
                replies.push(
                    rx.recv_timeout(REPLY_BOUND)
                        .expect("swap never loses replies")
                        .expect("infer ok")
                        .logits,
                );
            }
            replies
        }));
    }
    std::thread::sleep(Duration::from_millis(5));
    let version = e.swap_model("m", tiny_compiled("deepspeech", 2), None).unwrap();
    assert_eq!(version, 2);

    let mut replies = Vec::new();
    for h in handles {
        replies.extend(h.join().unwrap());
    }
    // post-drain: the serving weights are v2
    let ref2 = e.infer("m", input).unwrap().logits;
    assert_ne!(ref1, ref2, "seeds 1 and 2 must differ");
    // atomicity: every concurrent reply is wholly one version
    let (mut v1, mut v2) = (0u64, 0u64);
    for logits in &replies {
        if *logits == ref1 {
            v1 += 1;
        } else if *logits == ref2 {
            v2 += 1;
        } else {
            panic!("reply matches neither version: torn swap");
        }
    }
    assert_eq!(v1 + v2, (PRODUCERS * PER_PRODUCER) as u64);
    assert_eq!(e.store().version("m"), Some(2));
    assert_eq!(e.metrics().model_store_counts().2, 1, "exactly one swap");
    assert_eq!(
        e.metrics().completed.load(Relaxed),
        (PRODUCERS * PER_PRODUCER) as u64 + 2 // + the two reference infers
    );
}

/// Delegating wrapper whose forward sleeps first: pins the dispatch
/// guard inside the forward long enough for the test to hot-swap
/// mid-flight, deterministically.
struct Slowed {
    inner: CompiledModel,
    delay: Duration,
}

impl Model for Slowed {
    fn input_len(&self) -> usize {
        self.inner.input_len()
    }
    fn output_len(&self) -> usize {
        self.inner.output_len()
    }
    fn forward_timed(&self, frames: &[f32]) -> (Vec<f32>, Vec<LayerTiming>) {
        std::thread::sleep(self.delay);
        Model::forward_timed(&self.inner, frames)
    }
    fn forward_batch(&self, frames: &[&[f32]]) -> Vec<(Vec<f32>, Vec<LayerTiming>)> {
        std::thread::sleep(self.delay);
        Model::forward_batch(&self.inner, frames)
    }
    fn route_ops(&self, group: usize) -> Vec<OpDesc> {
        Model::route_ops(&self.inner, group)
    }
    fn resident_bytes(&self) -> usize {
        Model::resident_bytes(&self.inner)
    }
    fn describe(&self) -> String {
        format!("slowed({})", self.inner.describe())
    }
}

#[test]
fn in_flight_dispatch_finishes_on_v1_weights_across_a_swap() {
    let v1 = tiny_compiled("mlp", 1);
    let v2 = tiny_compiled("mlp", 2);
    let input = vec![0.1f32; Model::input_len(&v1)];
    let ref1 = Model::forward_timed(&v1, &input).0;
    let ref2 = Model::forward_timed(&v2, &input).0;
    assert_ne!(ref1, ref2);

    let e = Engine::new(EngineConfig {
        workers: 1,
        sched: SchedulerConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            max_queue: 64,
            slo: Duration::from_secs(5),
            ..SchedulerConfig::default()
        },
        router: RouterConfig::default(),
        store: StoreConfig::default(),
    });
    e.register_model("m", Slowed { inner: v1, delay: Duration::from_millis(500) }).unwrap();
    let rx1 = e.try_submit("m", input.clone()).unwrap();
    // wait for the worker to take its dispatch hold (the guard is
    // captured before the slowed forward starts sleeping)
    let t0 = std::time::Instant::now();
    while e.store().entry_stats("m").unwrap().in_flight == 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "dispatch never started");
        std::thread::sleep(Duration::from_millis(1));
    }
    // swap while the v1 guard is live: the drain protocol is the guard
    // lifetime — no wait, no lock handoff, v1 just finishes on v1
    let version = e.swap_model("m", v2, None).unwrap();
    assert_eq!(version, 2);
    assert_eq!(e.store().entry_stats("m").unwrap().in_flight, 1, "guard still live");
    let r1 = rx1.recv_timeout(REPLY_BOUND).unwrap().unwrap();
    assert_eq!(r1.logits, ref1, "in-flight dispatch must finish on the v1 weights it captured");
    // the next dispatch serves v2 (and is no longer slowed)
    let r2 = e.infer("m", input).unwrap();
    assert_eq!(r2.logits, ref2, "post-swap dispatches must serve v2");
    let (loads, evictions, swaps) = e.metrics().model_store_counts();
    assert_eq!((loads, evictions, swaps), (2, 0, 1));
    e.shutdown();
}

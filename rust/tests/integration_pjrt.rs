//! Cross-language integration: the Rust packers + native kernels must
//! agree bit-for-bit with the AOT-lowered Pallas kernels executed via
//! PJRT — the strongest three-layer consistency check in the repo.
//!
//! Requires `make artifacts` and a build with `--features pjrt`; every
//! test skips gracefully when artifacts are missing, and the whole file
//! is compiled out without the feature.
#![cfg(feature = "pjrt")]

use fullpack::kernels::{gemv, pack_activations, ActVec};
use fullpack::pack::{BitWidth, PackedMatrix, Variant};
use fullpack::runtime::{Runtime, Tensor};
use fullpack::util::proptest_lite::Gen;

fn runtime() -> Option<Runtime> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(dir).expect("load runtime"))
}

fn rand_in(g: &mut Gen, bits: BitWidth, n: usize) -> Vec<i8> {
    let (lo, hi) = bits.value_range();
    (0..n).map(|_| g.i8_in(lo, hi)).collect()
}

#[test]
fn all_nine_variants_native_equals_pjrt() {
    let Some(rt) = runtime() else { return };
    let mut g = Gen::new(0xC0FFEE);
    let (z, k) = (256usize, 256usize);
    for variant in Variant::PAPER_VARIANTS {
        let name = format!("gemv_{}_{z}x{k}", variant.name());
        let meta = rt.manifest().get(&name).unwrap_or_else(|| panic!("{name} missing"));

        let w = rand_in(&mut g, variant.w, z * k);
        let a = rand_in(&mut g, variant.a, k);
        let wp = PackedMatrix::from_i8(&w, z, k, variant.w).expect("pack weights");

        // native
        let packed_a;
        let act = if variant.a.is_sub_byte() {
            packed_a = pack_activations(&a, variant.a).unwrap();
            ActVec::Packed { bytes: &packed_a, bits: variant.a }
        } else {
            ActVec::I8(&a)
        };
        let mut native = vec![0i32; z];
        gemv(&wp, act, &mut native).unwrap();

        // PJRT (same packed bytes — the layouts must be identical)
        let w_tensor = if variant.w.is_sub_byte() {
            Tensor::u8(wp.bytes().to_vec(), meta.inputs[0].shape.clone())
        } else {
            Tensor::s8(w.clone(), meta.inputs[0].shape.clone())
        };
        let a_tensor = if variant.a.is_sub_byte() {
            Tensor::u8(pack_activations(&a, variant.a).unwrap(), meta.inputs[1].shape.clone())
        } else {
            Tensor::s8(a.clone(), meta.inputs[1].shape.clone())
        };
        let out = rt.execute(&name, &[w_tensor, a_tensor]).expect("pjrt exec");
        assert_eq!(out[0].as_s32().unwrap(), native.as_slice(), "{variant} PJRT != native");
    }
}

#[test]
fn w8a8_and_f32_baseline_artifacts() {
    let Some(rt) = runtime() else { return };
    let mut g = Gen::new(0xBEEF);
    let (z, k) = (256usize, 256usize);
    // w8a8
    let w = rand_in(&mut g, BitWidth::B8, z * k);
    let a = rand_in(&mut g, BitWidth::B8, k);
    let wp = PackedMatrix::from_i8(&w, z, k, BitWidth::B8).unwrap();
    let mut native = vec![0i32; z];
    gemv(&wp, ActVec::I8(&a), &mut native).unwrap();
    let out = rt
        .execute(
            "gemv_w8a8_256x256",
            &[Tensor::s8(w, vec![z, k]), Tensor::s8(a, vec![k])],
        )
        .unwrap();
    assert_eq!(out[0].as_s32().unwrap(), native.as_slice());

    // f32
    let wf: Vec<f32> = (0..z * k).map(|i| ((i % 37) as f32 - 18.0) * 0.03).collect();
    let af: Vec<f32> = (0..k).map(|i| ((i % 11) as f32 - 5.0) * 0.1).collect();
    let out = rt
        .execute(
            "gemv_f32_256x256",
            &[Tensor::f32(wf.clone(), vec![z, k]), Tensor::f32(af.clone(), vec![k])],
        )
        .unwrap();
    let got = out[0].as_f32().unwrap();
    for r in 0..z {
        let expect: f32 = wf[r * k..(r + 1) * k].iter().zip(&af).map(|(x, y)| x * y).sum();
        assert!((got[r] - expect).abs() < 1e-2, "row {r}: {} vs {expect}", got[r]);
    }
}

#[test]
fn lstm_step_artifact_runs_and_is_stable() {
    let Some(rt) = runtime() else { return };
    let name = "lstm_step_w4a8_tiny";
    let meta = rt.manifest().get(name).expect("tiny lstm artifact").clone();
    let hidden = meta.meta["hidden"] as usize;
    let mut g = Gen::new(0xDADA);

    let w = rand_in(&mut g, BitWidth::B4, 4 * hidden * hidden);
    let wp = PackedMatrix::from_i8(&w, 4 * hidden, hidden, BitWidth::B4).unwrap();
    let x = rand_in(&mut g, BitWidth::B8, hidden);
    let h = vec![0i8; hidden];
    let c = vec![0.0f32; hidden];
    let bias = vec![0.0f32; 4 * hidden];

    let inputs = vec![
        Tensor::u8(wp.bytes().to_vec(), meta.inputs[0].shape.clone()),
        Tensor::u8(wp.bytes().to_vec(), meta.inputs[1].shape.clone()),
        Tensor::f32(bias, meta.inputs[2].shape.clone()),
        Tensor::s8(x, meta.inputs[3].shape.clone()),
        Tensor::s8(h, meta.inputs[4].shape.clone()),
        Tensor::f32(c, meta.inputs[5].shape.clone()),
        Tensor::scalar_f32(0.05),
        Tensor::scalar_f32(1.0 / 127.0),
        Tensor::scalar_f32(0.02),
    ];
    let out1 = rt.execute(name, &inputs).expect("lstm step");
    assert_eq!(out1.len(), 3); // h_packed, c, h_f32
    let h_f32 = out1[2].as_f32().unwrap();
    assert_eq!(h_f32.len(), hidden);
    assert!(h_f32.iter().all(|v| v.is_finite() && v.abs() <= 1.0), "tanh-bounded");
    // determinism
    let out2 = rt.execute(name, &inputs).expect("lstm step 2");
    assert_eq!(out1[2], out2[2]);
    // cell state evolves from zero given nonzero input
    let c_next = out1[1].as_f32().unwrap();
    assert!(c_next.iter().any(|&v| v != 0.0));
}

#[test]
fn deepspeech_tiny_artifact_forward() {
    let Some(rt) = runtime() else { return };
    for variant in ["w4a8", "w1a1", "f32"] {
        let name = format!("deepspeech_tiny_{variant}");
        let meta = rt.manifest().get(&name).expect("tiny e2e artifact").clone();
        let t = meta.meta["time_steps"] as usize;
        let n_in = meta.meta["n_input"] as usize;
        let frames: Vec<f32> = (0..t * n_in).map(|i| (i as f32 * 0.01).sin()).collect();
        let out = rt
            .execute(&name, &[Tensor::f32(frames, vec![t, n_in])])
            .expect("tiny forward");
        let logits = out[0].as_f32().unwrap();
        assert_eq!(logits.len(), t * meta.meta["n_output"] as usize);
        assert!(logits.iter().all(|v| v.is_finite()), "{name}");
    }
}

//! Workload-harness integration (DESIGN.md §11): the spec → sampler →
//! loadgen → report pipeline end-to-end.
//!
//! - determinism: same seed ⇒ byte-identical sampled mix files and
//!   identical virtual traces;
//! - live replay: a bursty mixed-model mix against the real engine
//!   answers every request exactly once, with per-model dispatch sums
//!   reconciling against the engine's own `Metrics`;
//! - policy mirroring: on a count-only pinned mix, the virtual DES and
//!   the live engine take bit-identical admission decisions (flush
//!   reasons, sealed batch sizes, typed sheds, per-model splits) —
//!   they run the same `Scheduler` state machine;
//! - shedding: a tail-heavy bursty storm sheds with typed reasons that
//!   reconcile exactly through `build_report`;
//! - report: exact percentiles match a brute-force sort oracle;
//! - spec: malformed mix JSON is rejected with typed errors.

use std::time::Duration;

use fullpack::coordinator::{
    EngineConfig, FaultPlan, ModelSpec, RouterConfig, SchedulerConfig, ShedReason, StoreConfig,
};
use fullpack::models::ModelSize;
use fullpack::pack::Variant;
use fullpack::workload::{
    build_report, run_live, run_live_with, run_virtual, run_virtual_with, ArrivalProcess,
    Dist, MixModel, MixSpace, Outcome, WorkloadMix,
};

/// A small sampling space so virtual runs stay fast.
fn small_space() -> MixSpace {
    let mut space = MixSpace::default_space();
    space.clients = (1, 2);
    space.requests_per_client = (4, 6);
    space
}

fn spec(name: &str, model: &str, variant: &str) -> ModelSpec {
    ModelSpec {
        name: name.to_string(),
        model: model.to_string(),
        variant: Variant::parse(variant).unwrap(),
        size: ModelSize::Tiny,
        seed: 7,
        pin: false,
    }
}

/// A hand-built bursty two-model mix for the live-engine test.  The
/// queue is deep enough that the tiny models never shed, so every
/// planned request completes.
fn bursty_two_model_mix() -> WorkloadMix {
    WorkloadMix {
        name: "bursty-two-model".to_string(),
        seed: 42,
        clients: 2,
        requests_per_client: 8,
        arrival: ArrivalProcess::BurstyOnOff { on_us: 2_000, off_us: 1_000, rate_rps: 2_000.0 },
        burst: Dist::Uniform { lo: 1.0, hi: 3.0 },
        seq_fill: Dist::Uniform { lo: 0.5, hi: 1.0 },
        models: vec![
            MixModel { spec: spec("ds", "deepspeech", "w4a8"), weight: 2.0 },
            MixModel { spec: spec("mlp", "mlp", "w2a8"), weight: 1.0 },
        ],
        engine: EngineConfig {
            workers: 2,
            sched: SchedulerConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                max_queue: 256,
                // lax enough that tiny-model backlogs never trip the
                // over-budget admission rule: every request completes
                slo: Duration::from_secs(2),
                ..SchedulerConfig::default()
            },
            router: RouterConfig::default(),
            store: StoreConfig::default(),
        },
    }
}

#[test]
fn same_seed_yields_byte_identical_mixes_and_traces() {
    let space = small_space();
    let a = space.sample_all(7, 4);
    let b = space.sample_all(7, 4);
    assert_eq!(a.len(), 4);
    for (ma, mb) in a.iter().zip(&b) {
        assert_eq!(ma, mb);
        assert_eq!(ma.to_json(), mb.to_json(), "sampled mix files must be byte-identical");
        let ta = run_virtual(ma).unwrap();
        let tb = run_virtual(mb).unwrap();
        assert_eq!(ta, tb, "{}: virtual trace must be reproducible", ma.name);
        assert_eq!(ta.records.len(), ma.total_requests());
    }
    // a different seed changes the sample
    let c = space.sample_all(8, 4);
    assert!(a.iter().zip(&c).any(|(x, y)| x != y), "seed must steer the sampler");
}

#[test]
fn sampled_mixes_roundtrip_through_files() {
    let dir = std::env::temp_dir().join("fullpack_workload_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    for mix in small_space().sample_all(11, 3) {
        let path = dir.join(format!("{}.json", mix.name));
        let path = path.to_str().unwrap();
        mix.save(path).unwrap();
        let back = WorkloadMix::load(path).unwrap();
        assert_eq!(mix, back, "save -> load must be the identity");
        // serializing the reloaded mix reproduces the file bytes
        assert_eq!(std::fs::read_to_string(path).unwrap(), back.to_json());
    }
}

#[test]
fn live_bursty_mixed_mix_replies_exactly_once_and_reconciles() {
    let mix = bursty_two_model_mix();
    // verify=true: every completed reply is checked bit-for-bit against
    // an unbatched reference forward of the same frames
    let trace = run_live(&mix, true).unwrap();
    let total = mix.total_requests();
    assert_eq!(trace.records.len(), total, "every planned request resolved");

    // exactly once: each (client, index) slot appears once, in order
    for (i, r) in trace.records.iter().enumerate() {
        assert_eq!(r.client * mix.requests_per_client + r.index, i);
    }

    // trace tallies reconcile with the engine's own counters
    let s = &trace.snapshot;
    let count = |o: Outcome| trace.records.iter().filter(|r| r.outcome == o).count() as u64;
    assert_eq!(s.requests, total as u64, "submit counts sheds too");
    assert_eq!(s.completed, count(Outcome::Completed));
    assert_eq!(s.errors, count(Outcome::Error));
    assert_eq!(count(Outcome::Error), 0, "healthy mix must not error");
    let shed = trace.records.iter().filter(|r| r.outcome.is_shed()).count() as u64;
    assert_eq!(shed, 0, "deep queue + lax SLO must not shed");
    assert_eq!(
        s.batched_requests + s.singleton_requests,
        s.completed + s.errors,
        "dispatch split covers everything a worker served"
    );

    // per-model dispatch sums match the per-model record tallies
    for (mi, m) in mix.models.iter().enumerate() {
        let served = trace
            .records
            .iter()
            .filter(|r| r.model == mi && r.outcome == Outcome::Completed)
            .count() as u64;
        let counters = s
            .per_model
            .iter()
            .find(|(n, _)| n == &m.spec.name)
            .map(|(_, c)| *c)
            .unwrap_or_default();
        assert_eq!(counters.completed, served, "model {:?}", m.spec.name);
        assert_eq!(
            counters.batched_requests + counters.singleton_requests,
            served,
            "model {:?} dispatch split",
            m.spec.name
        );
    }

    // the report layer accepts the trace (it re-runs all of the above
    // reconciliation and fails on any mismatch)
    let report = build_report(&mix, &trace).unwrap();
    assert_eq!(report.issued, total as u64);
    assert_eq!(report.mode, "live");
    assert_eq!(report.per_model.len(), 2);
}

/// A mix whose admission decisions are pure *counting*: `max_batch`
/// seals happen at admission, the SLO is orders of magnitude beyond
/// any modeled dispatch cost (the budget rule can never race wall-clock
/// jitter), and a worker stall covers the whole submission window so no
/// pop interleaves with admission.  Under those conditions the sequence
/// of scheduler decisions is a pure function of the arrival order —
/// which both replay modes take from the same seeded plan.
fn pinned_count_only_mix() -> WorkloadMix {
    WorkloadMix {
        name: "pinned-count-only".to_string(),
        seed: 1234,
        clients: 1,
        requests_per_client: 24,
        arrival: ArrivalProcess::Deterministic { interval_us: 1 },
        burst: Dist::Const(1.0),
        seq_fill: Dist::Const(1.0),
        models: vec![
            MixModel { spec: spec("ds", "deepspeech", "w4a8"), weight: 1.0 },
            MixModel { spec: spec("mlp", "mlp", "w2a8"), weight: 1.0 },
        ],
        engine: EngineConfig {
            workers: 1,
            sched: SchedulerConfig {
                max_batch: 3,
                max_wait: Duration::from_millis(40),
                max_queue: 4,
                slo: Duration::from_secs(30),
                cost_flush: true,
                shed_over_budget: true,
            },
            router: RouterConfig::default(),
            store: StoreConfig::default(),
        },
    }
}

#[test]
fn virtual_des_mirrors_live_admission_bit_exactly() {
    let mix = pinned_count_only_mix();
    // stall the (single) worker well past the ~24µs-planned submission
    // window: admission runs pop-free in both modes, so queue depths,
    // seal points and sheds depend only on the shared plan
    let stall = FaultPlan {
        worker_stall: Duration::from_millis(300),
        ..FaultPlan::default()
    };
    let live = run_live_with(&mix, false, &stall).unwrap();
    let virt = run_virtual_with(&mix, &stall).unwrap();
    let (l, v) = (&live.snapshot, &virt.snapshot);

    // the policy made the same decisions in both worlds
    assert_eq!(l.requests, v.requests);
    assert_eq!(l.completed, v.completed);
    assert_eq!((l.errors, v.errors), (0, 0));
    assert_eq!(l.flushes, v.flushes, "flush decisions must be bit-identical");
    assert_eq!(l.batch_sizes, v.batch_sizes, "sealed memberships must match");
    assert_eq!(l.sheds, v.sheds, "typed shed counts must match");
    assert_eq!(l.batched_requests, v.batched_requests);
    assert_eq!(l.singleton_requests, v.singleton_requests);
    assert_eq!(l.batched_dispatches, v.batched_dispatches);
    assert_eq!(l.max_queue_depth, v.max_queue_depth);

    // and the mix actually exercised the policy: Full seals at
    // admission, Deadline seals of the stalled remainders, queue-full
    // sheds once each model queue hit max_queue — never over-budget
    // (the SLO is 30s)
    assert!(l.flushes.0 > 0, "expected Full seals (got {:?})", l.flushes);
    assert!(l.flushes.2 > 0, "expected Deadline seals (got {:?})", l.flushes);
    assert_eq!(l.flushes.1, 0, "30s SLO must never budget-seal");
    assert!(l.sheds.0 > 0, "4-deep queues must shed under the stall");
    assert_eq!(l.sheds.1, 0, "30s SLO must never shed over-budget");
    // single worker: EDF order is served exactly, nothing is stolen
    assert_eq!((l.edf_inversions, l.stolen_dispatches), (0, 0));
    assert_eq!((v.edf_inversions, v.stolen_dispatches), (0, 0));

    // per-model splits agree on every timing-free counter
    assert_eq!(l.per_model.len(), v.per_model.len());
    for ((ln, lc), (vn, vc)) in l.per_model.iter().zip(&v.per_model) {
        assert_eq!(ln, vn);
        assert_eq!(lc.completed, vc.completed, "{ln}");
        assert_eq!(lc.batched_requests, vc.batched_requests, "{ln}");
        assert_eq!(lc.singleton_requests, vc.singleton_requests, "{ln}");
        assert_eq!(lc.batched_dispatches, vc.batched_dispatches, "{ln}");
        assert_eq!(lc.sheds_queue_full, vc.sheds_queue_full, "{ln}");
        assert_eq!(lc.sheds_over_budget, vc.sheds_over_budget, "{ln}");
        assert_eq!(lc.max_queue_depth, vc.max_queue_depth, "{ln}");
    }

    // every planned request meets the same fate in both worlds
    assert_eq!(live.records.len(), virt.records.len());
    for (lr, vr) in live.records.iter().zip(&virt.records) {
        assert_eq!((lr.client, lr.index, lr.model), (vr.client, vr.index, vr.model));
        assert_eq!(
            lr.outcome, vr.outcome,
            "client {} index {}: live and virtual disagree",
            lr.client, lr.index
        );
    }

    // both traces survive the report layer's exact reconciliation, and
    // the policy columns agree between the two reports
    let lrep = build_report(&mix, &live).unwrap();
    let vrep = build_report(&mix, &virt).unwrap();
    assert_eq!(lrep.flushes, vrep.flushes);
    assert_eq!(lrep.shed_queue_full, vrep.shed_queue_full);
    assert_eq!(lrep.shed_over_budget, vrep.shed_over_budget);
    assert_eq!(lrep.completed, vrep.completed);
}

#[test]
fn budgeted_store_cold_sheds_mirror_between_live_and_virtual() {
    // the count-only mirror mix under a 1-byte residency budget: at
    // most one model is warm at a time, so the alternating two-model
    // traffic churns the store — every admission of the cold model
    // sheds typed ColdModel and synchronously swaps residency.  The
    // decision sequence is a pure function of the shared arrival plan,
    // so the live engine and the virtual DES must take bit-identical
    // cold-shed, load and eviction decisions (DESIGN.md §14).
    let mut mix = pinned_count_only_mix();
    mix.name = "budgeted-churn".to_string();
    mix.engine.store.budget_bytes = Some(1);
    let stall = FaultPlan {
        worker_stall: Duration::from_millis(300),
        ..FaultPlan::default()
    };
    let live = run_live_with(&mix, false, &stall).unwrap();
    let virt = run_virtual_with(&mix, &stall).unwrap();
    let (l, v) = (&live.snapshot, &virt.snapshot);

    assert!(l.sheds.2 > 0, "a 1-byte budget must shed cold admissions (got {:?})", l.sheds);
    assert_eq!(l.sheds, v.sheds, "typed shed counts (cold included) must mirror");
    assert_eq!(l.store, v.store, "store load/eviction/swap counters must mirror");
    assert!(l.store.0 > 0 && l.store.1 > 0, "churn must load and evict (got {:?})", l.store);
    assert_eq!(l.requests, v.requests);
    assert_eq!(l.completed, v.completed);
    assert_eq!((l.errors, v.errors), (0, 0));

    // every planned request meets the same fate in both worlds
    assert_eq!(live.records.len(), virt.records.len());
    for (lr, vr) in live.records.iter().zip(&virt.records) {
        assert_eq!((lr.client, lr.index, lr.model), (vr.client, vr.index, vr.model));
        assert_eq!(
            lr.outcome, vr.outcome,
            "client {} index {}: live and virtual disagree",
            lr.client, lr.index
        );
    }
    for ((ln, lc), (vn, vc)) in l.per_model.iter().zip(&v.per_model) {
        assert_eq!(ln, vn);
        assert_eq!(lc.sheds_cold_model, vc.sheds_cold_model, "{ln}");
        assert_eq!(lc.loads, vc.loads, "{ln}");
        assert_eq!(lc.evictions, vc.evictions, "{ln}");
    }

    // both traces reconcile through the report layer, store columns too
    let lrep = build_report(&mix, &live).unwrap();
    let vrep = build_report(&mix, &virt).unwrap();
    assert!(lrep.shed_cold_model > 0);
    assert_eq!(lrep.shed_cold_model, vrep.shed_cold_model);
    assert_eq!(
        (lrep.store_loads, lrep.store_evictions, lrep.store_swaps),
        (vrep.store_loads, vrep.store_evictions, vrep.store_swaps)
    );
}

#[test]
fn tail_heavy_bursty_storm_sheds_typed_and_reconciles() {
    // a burst storm against shallow queues: arrivals land ns apart
    // while every dispatch costs the full modeled service time, so the
    // 3-deep per-model queues overflow and shed with typed reasons
    let mut mix = bursty_two_model_mix();
    mix.name = "tail-heavy-bursty".to_string();
    mix.clients = 4;
    mix.requests_per_client = 32;
    mix.arrival = ArrivalProcess::BurstyOnOff { on_us: 500, off_us: 2_000, rate_rps: 5e8 };
    mix.burst = Dist::Uniform { lo: 2.0, hi: 6.0 };
    mix.engine.workers = 2;
    mix.engine.sched.max_batch = 4;
    mix.engine.sched.max_queue = 3;
    mix.engine.sched.shed_over_budget = false; // isolate queue-full shedding
    let trace = run_virtual(&mix).unwrap();

    let count = |o: Outcome| trace.records.iter().filter(|r| r.outcome == o).count() as u64;
    let shed_qf = count(Outcome::Shed(ShedReason::QueueFull));
    let shed_ob = count(Outcome::Shed(ShedReason::OverBudget));
    assert!(shed_qf > 0, "the storm must overflow the 3-deep queues");
    assert_eq!(shed_ob, 0, "over-budget shedding is disabled here");
    assert!(count(Outcome::Completed) > 0, "admitted requests still complete");
    assert_eq!(trace.snapshot.sheds, (shed_qf, shed_ob, 0), "typed counters reconcile");

    // the report carries the typed split and reconciles it exactly
    let report = build_report(&mix, &trace).unwrap();
    assert_eq!(report.issued, mix.total_requests() as u64);
    assert_eq!(report.shed_queue_full, shed_qf);
    assert_eq!(report.shed_over_budget, shed_ob);
    assert_eq!(report.shed, shed_qf + shed_ob);
    assert_eq!(report.completed + report.errors + report.shed, report.issued);
    let per_model_shed: u64 = report.per_model.iter().map(|m| m.shed).sum();
    assert_eq!(per_model_shed, report.shed, "per-model sheds cover the global split");
    assert!(report.max_queue_depth <= mix.engine.sched.max_queue as u64);

    // the reconciliation is exact, not approximate: a lost shed is an
    // error, not a report
    let mut tampered = trace.clone();
    tampered.snapshot.sheds.0 += 1;
    assert!(build_report(&mix, &tampered).is_err());

    // over-budget admission control on the same storm: a sub-ms SLO
    // that no modeled dispatch can meet sheds typed OverBudget at the
    // front door (deterministically — the backlog test is cost-model
    // arithmetic, not timing)
    let mut strict = mix.clone();
    strict.name = "tail-heavy-strict-slo".to_string();
    strict.engine.sched.shed_over_budget = true;
    strict.engine.sched.slo = Duration::ZERO;
    let trace = run_virtual(&strict).unwrap();
    let count = |o: Outcome| trace.records.iter().filter(|r| r.outcome == o).count() as u64;
    assert_eq!(
        count(Outcome::Shed(ShedReason::OverBudget)),
        strict.total_requests() as u64,
        "a zero SLO budget admits nothing"
    );
    let report = build_report(&strict, &trace).unwrap();
    assert_eq!(report.shed_over_budget, report.issued);
    assert_eq!(report.completed, 0);
    assert_eq!(report.p99_us, 0, "no completions, no percentiles");
}

#[test]
fn report_percentiles_match_sort_oracle() {
    let mix = small_space().sample(19, 0);
    let trace = run_virtual(&mix).unwrap();
    let report = build_report(&mix, &trace).unwrap();

    // brute-force oracle: sort completed latencies, take nearest-rank
    let mut lat: Vec<u64> = trace
        .records
        .iter()
        .filter(|r| r.outcome == Outcome::Completed)
        .map(|r| r.latency_us)
        .collect();
    lat.sort_unstable();
    assert!(!lat.is_empty());
    let oracle = |q: f64| {
        let rank = ((lat.len() as f64 * q).ceil() as usize).clamp(1, lat.len());
        lat[rank - 1]
    };
    assert_eq!(report.p50_us, oracle(0.50));
    assert_eq!(report.p95_us, oracle(0.95));
    assert_eq!(report.p99_us, oracle(0.99));
    assert_eq!(report.max_us, *lat.last().unwrap());
    let mean = lat.iter().sum::<u64>() as f64 / lat.len() as f64;
    assert!((report.mean_us - mean).abs() < 1e-9);

    // per-model lines use the same rule over their own subsets
    for (mi, line) in report.per_model.iter().enumerate() {
        let mut sub: Vec<u64> = trace
            .records
            .iter()
            .filter(|r| r.model == mi && r.outcome == Outcome::Completed)
            .map(|r| r.latency_us)
            .collect();
        sub.sort_unstable();
        if sub.is_empty() {
            assert_eq!(line.p50_us, 0);
            continue;
        }
        let rank = |q: f64| ((sub.len() as f64 * q).ceil() as usize).clamp(1, sub.len());
        assert_eq!(line.p50_us, sub[rank(0.50) - 1], "{}", line.name);
        assert_eq!(line.p99_us, sub[rank(0.99) - 1], "{}", line.name);
    }
}

#[test]
fn malformed_mix_files_rejected_with_typed_errors() {
    let dir = std::env::temp_dir().join("fullpack_workload_malformed");
    std::fs::create_dir_all(&dir).unwrap();
    let cases: &[(&str, &str, &str)] = &[
        ("not_json", "{", "mix JSON"),
        ("no_seed", r#"{"name": "m", "clients": 1}"#, "missing seed"),
        (
            "bad_arrival",
            r#"{"name": "m", "seed": 1, "clients": 1, "requests_per_client": 1,
               "arrival": {"kind": "fractal"},
               "models": [{"name": "ds", "model": "deepspeech", "size": "tiny"}]}"#,
            "unknown",
        ),
        (
            "zero_clients",
            r#"{"name": "m", "seed": 1, "clients": 0, "requests_per_client": 1,
               "arrival": {"kind": "poisson", "rate_rps": 10},
               "models": [{"name": "ds", "model": "deepspeech", "size": "tiny"}]}"#,
            "clients must be >= 1",
        ),
        (
            "fill_over_one",
            r#"{"name": "m", "seed": 1, "clients": 1, "requests_per_client": 1,
               "arrival": {"kind": "poisson", "rate_rps": 10},
               "seq_fill": {"kind": "const", "value": 1.5},
               "models": [{"name": "ds", "model": "deepspeech", "size": "tiny"}]}"#,
            "seq_fill must lie in (0, 1]",
        ),
    ];
    for (stem, text, want) in cases {
        let path = dir.join(format!("{stem}.json"));
        std::fs::write(&path, text).unwrap();
        let err = WorkloadMix::load(path.to_str().unwrap()).unwrap_err().to_string();
        assert!(err.contains(want), "{stem}: error {err:?} should mention {want:?}");
    }
    // a missing file is also a typed error, not a panic
    let err = WorkloadMix::load("/nonexistent/mix.json").unwrap_err().to_string();
    assert!(err.contains("reading mix"), "{err}");
}

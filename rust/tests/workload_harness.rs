//! Workload-harness integration (DESIGN.md §11): the spec → sampler →
//! loadgen → report pipeline end-to-end.
//!
//! - determinism: same seed ⇒ byte-identical sampled mix files and
//!   identical virtual traces;
//! - live replay: a bursty mixed-model mix against the real engine
//!   answers every request exactly once, with per-model dispatch sums
//!   reconciling against the engine's own `Metrics`;
//! - report: exact percentiles match a brute-force sort oracle;
//! - spec: malformed mix JSON is rejected with typed errors.

use fullpack::coordinator::{BatcherConfig, EngineConfig, ModelSpec, RouterConfig};
use fullpack::models::ModelSize;
use fullpack::pack::Variant;
use fullpack::workload::{
    build_report, run_live, run_virtual, ArrivalProcess, Dist, MixModel, MixSpace, Outcome,
    WorkloadMix,
};

/// A small sampling space so virtual runs stay fast.
fn small_space() -> MixSpace {
    let mut space = MixSpace::default_space();
    space.clients = (1, 2);
    space.requests_per_client = (4, 6);
    space
}

/// A hand-built bursty two-model mix for the live-engine test.
fn bursty_two_model_mix() -> WorkloadMix {
    let spec = |name: &str, model: &str, variant: &str| ModelSpec {
        name: name.to_string(),
        model: model.to_string(),
        variant: Variant::parse(variant).unwrap(),
        size: ModelSize::Tiny,
        seed: 7,
    };
    WorkloadMix {
        name: "bursty-two-model".to_string(),
        seed: 42,
        clients: 2,
        requests_per_client: 8,
        arrival: ArrivalProcess::BurstyOnOff { on_us: 2_000, off_us: 1_000, rate_rps: 2_000.0 },
        burst: Dist::Uniform { lo: 1.0, hi: 3.0 },
        seq_fill: Dist::Uniform { lo: 0.5, hi: 1.0 },
        models: vec![
            MixModel { spec: spec("ds", "deepspeech", "w4a8"), weight: 2.0 },
            MixModel { spec: spec("mlp", "mlp", "w2a8"), weight: 1.0 },
        ],
        engine: EngineConfig {
            workers: 2,
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: std::time::Duration::from_millis(1),
                max_queue: 256,
            },
            router: RouterConfig::default(),
        },
    }
}

#[test]
fn same_seed_yields_byte_identical_mixes_and_traces() {
    let space = small_space();
    let a = space.sample_all(7, 4);
    let b = space.sample_all(7, 4);
    assert_eq!(a.len(), 4);
    for (ma, mb) in a.iter().zip(&b) {
        assert_eq!(ma, mb);
        assert_eq!(ma.to_json(), mb.to_json(), "sampled mix files must be byte-identical");
        let ta = run_virtual(ma).unwrap();
        let tb = run_virtual(mb).unwrap();
        assert_eq!(ta, tb, "{}: virtual trace must be reproducible", ma.name);
        assert_eq!(ta.records.len(), ma.total_requests());
    }
    // a different seed changes the sample
    let c = space.sample_all(8, 4);
    assert!(a.iter().zip(&c).any(|(x, y)| x != y), "seed must steer the sampler");
}

#[test]
fn sampled_mixes_roundtrip_through_files() {
    let dir = std::env::temp_dir().join("fullpack_workload_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    for mix in small_space().sample_all(11, 3) {
        let path = dir.join(format!("{}.json", mix.name));
        let path = path.to_str().unwrap();
        mix.save(path).unwrap();
        let back = WorkloadMix::load(path).unwrap();
        assert_eq!(mix, back, "save -> load must be the identity");
        // serializing the reloaded mix reproduces the file bytes
        assert_eq!(std::fs::read_to_string(path).unwrap(), back.to_json());
    }
}

#[test]
fn live_bursty_mixed_mix_replies_exactly_once_and_reconciles() {
    let mix = bursty_two_model_mix();
    // verify=true: every completed reply is checked bit-for-bit against
    // an unbatched reference forward of the same frames
    let trace = run_live(&mix, true).unwrap();
    let total = mix.total_requests();
    assert_eq!(trace.records.len(), total, "every planned request resolved");

    // exactly once: each (client, index) slot appears once, in order
    for (i, r) in trace.records.iter().enumerate() {
        assert_eq!(r.client * mix.requests_per_client + r.index, i);
    }

    // trace tallies reconcile with the engine's own counters
    let s = &trace.snapshot;
    let count = |o: Outcome| trace.records.iter().filter(|r| r.outcome == o).count() as u64;
    assert_eq!(s.requests, total as u64, "submit counts sheds too");
    assert_eq!(s.completed, count(Outcome::Completed));
    assert_eq!(s.errors, count(Outcome::Error));
    assert_eq!(count(Outcome::Error), 0, "healthy mix must not error");
    assert_eq!(
        s.batched_requests + s.singleton_requests,
        s.completed + s.errors,
        "dispatch split covers everything a worker served"
    );

    // per-model dispatch sums match the per-model record tallies
    for (mi, m) in mix.models.iter().enumerate() {
        let served = trace
            .records
            .iter()
            .filter(|r| r.model == mi && r.outcome == Outcome::Completed)
            .count() as u64;
        let counters = s
            .per_model
            .iter()
            .find(|(n, _)| n == &m.spec.name)
            .map(|(_, c)| *c)
            .unwrap_or_default();
        assert_eq!(counters.completed, served, "model {:?}", m.spec.name);
        assert_eq!(
            counters.batched_requests + counters.singleton_requests,
            served,
            "model {:?} dispatch split",
            m.spec.name
        );
    }

    // the report layer accepts the trace (it re-runs all of the above
    // reconciliation and fails on any mismatch)
    let report = build_report(&mix, &trace).unwrap();
    assert_eq!(report.issued, total as u64);
    assert_eq!(report.mode, "live");
    assert_eq!(report.per_model.len(), 2);
}

#[test]
fn report_percentiles_match_sort_oracle() {
    let mix = small_space().sample(19, 0);
    let trace = run_virtual(&mix).unwrap();
    let report = build_report(&mix, &trace).unwrap();

    // brute-force oracle: sort completed latencies, take nearest-rank
    let mut lat: Vec<u64> = trace
        .records
        .iter()
        .filter(|r| r.outcome == Outcome::Completed)
        .map(|r| r.latency_us)
        .collect();
    lat.sort_unstable();
    assert!(!lat.is_empty());
    let oracle = |q: f64| {
        let rank = ((lat.len() as f64 * q).ceil() as usize).clamp(1, lat.len());
        lat[rank - 1]
    };
    assert_eq!(report.p50_us, oracle(0.50));
    assert_eq!(report.p95_us, oracle(0.95));
    assert_eq!(report.p99_us, oracle(0.99));
    assert_eq!(report.max_us, *lat.last().unwrap());
    let mean = lat.iter().sum::<u64>() as f64 / lat.len() as f64;
    assert!((report.mean_us - mean).abs() < 1e-9);

    // per-model lines use the same rule over their own subsets
    for (mi, line) in report.per_model.iter().enumerate() {
        let mut sub: Vec<u64> = trace
            .records
            .iter()
            .filter(|r| r.model == mi && r.outcome == Outcome::Completed)
            .map(|r| r.latency_us)
            .collect();
        sub.sort_unstable();
        if sub.is_empty() {
            assert_eq!(line.p50_us, 0);
            continue;
        }
        let rank = |q: f64| ((sub.len() as f64 * q).ceil() as usize).clamp(1, sub.len());
        assert_eq!(line.p50_us, sub[rank(0.50) - 1], "{}", line.name);
        assert_eq!(line.p99_us, sub[rank(0.99) - 1], "{}", line.name);
    }
}

#[test]
fn malformed_mix_files_rejected_with_typed_errors() {
    let dir = std::env::temp_dir().join("fullpack_workload_malformed");
    std::fs::create_dir_all(&dir).unwrap();
    let cases: &[(&str, &str, &str)] = &[
        ("not_json", "{", "mix JSON"),
        ("no_seed", r#"{"name": "m", "clients": 1}"#, "missing seed"),
        (
            "bad_arrival",
            r#"{"name": "m", "seed": 1, "clients": 1, "requests_per_client": 1,
               "arrival": {"kind": "fractal"},
               "models": [{"name": "ds", "model": "deepspeech", "size": "tiny"}]}"#,
            "unknown",
        ),
        (
            "zero_clients",
            r#"{"name": "m", "seed": 1, "clients": 0, "requests_per_client": 1,
               "arrival": {"kind": "poisson", "rate_rps": 10},
               "models": [{"name": "ds", "model": "deepspeech", "size": "tiny"}]}"#,
            "clients must be >= 1",
        ),
        (
            "fill_over_one",
            r#"{"name": "m", "seed": 1, "clients": 1, "requests_per_client": 1,
               "arrival": {"kind": "poisson", "rate_rps": 10},
               "seq_fill": {"kind": "const", "value": 1.5},
               "models": [{"name": "ds", "model": "deepspeech", "size": "tiny"}]}"#,
            "seq_fill must lie in (0, 1]",
        ),
    ];
    for (stem, text, want) in cases {
        let path = dir.join(format!("{stem}.json"));
        std::fs::write(&path, text).unwrap();
        let err = WorkloadMix::load(path.to_str().unwrap()).unwrap_err().to_string();
        assert!(err.contains(want), "{stem}: error {err:?} should mention {want:?}");
    }
    // a missing file is also a typed error, not a panic
    let err = WorkloadMix::load("/nonexistent/mix.json").unwrap_err().to_string();
    assert!(err.contains("reading mix"), "{err}");
}

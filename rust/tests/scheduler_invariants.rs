//! Scheduler test battery (DESIGN.md §12): the admission-controlled
//! serving core pinned by seeded property storms and fault injection.
//!
//! Every property runs across ≥3 seeds ([`SEEDS`]):
//!
//! - **exactly-once** — N producer threads × M models under shed and
//!   deadline storms (tiny queues, 1ms deadlines, bad shapes mixed in):
//!   every accepted request is answered exactly once, every refusal is
//!   a typed [`Rejected`] with a retry hint, and the engine's counters
//!   reconcile with the clients' tallies by conservation law;
//! - **EDF** — the dequeue order of a randomly filled scheduler matches
//!   the min-deadline oracle exactly, and shard affinity flags steals
//!   and inversions truthfully;
//! - **cost-model flush points** — the marginal-latency rule seals at
//!   exactly the admission where one more column would break the SLO,
//!   both count-driven (at submit) and clock-driven (at `on_tick`), and
//!   the compiled cost curve feeding it is positive and monotone;
//! - **fault injection** ([`FaultPlan`]) — worker stalls delay but
//!   never lose replies, a slow model degrades only its own shard, and
//!   poisoned (dropped) reply channels neither hang workers nor leak
//!   requests.  Degradation is always a typed error or a late reply,
//!   never a deadlock: every wait in this battery is bounded.

use std::sync::atomic::Ordering::Relaxed;
use std::time::{Duration, Instant};

use fullpack::coordinator::{
    Engine, EngineConfig, FaultPlan, FlushReason, RouterConfig, Scheduler, SchedulerConfig,
    ShedReason, StoreConfig, SubmitError,
};
use fullpack::models::{CompiledModel, Model, ModelRegistry, ModelSize};
use fullpack::pack::Variant;
use fullpack::util::rng::SplitMix64;

/// The battery's seeds: every property must hold on each.
const SEEDS: [u64; 3] = [1, 2, 3];

const MS: u64 = 1_000_000;

/// A reply must land well inside this bound; waiting longer than this
/// is reported as a lost reply, not a hang.
const REPLY_BOUND: Duration = Duration::from_secs(30);

const ZOO: [&str; 3] = ["deepspeech", "mlp", "keyword-spotter"];

fn tiny(name: &str, seed: u64) -> CompiledModel {
    let g = ModelRegistry::global()
        .build(name, ModelSize::Tiny, Variant::parse("w4a8").unwrap(), seed)
        .unwrap();
    CompiledModel::compile(g).unwrap()
}

fn storm_engine(max_queue: usize, seed: u64) -> Engine {
    let e = Engine::new(EngineConfig {
        workers: 2,
        sched: SchedulerConfig {
            max_batch: 4,
            // deadline storm: forming batches expire every millisecond
            max_wait: Duration::from_millis(1),
            // shed storm: per-model queues a few entries deep
            max_queue,
            // lax SLO so sheds are queue-full typed, deterministically
            slo: Duration::from_secs(5),
            ..SchedulerConfig::default()
        },
        router: RouterConfig::default(),
        store: StoreConfig::default(),
    });
    for (i, name) in ZOO.iter().enumerate() {
        e.register_model(name, tiny(name, seed + i as u64)).unwrap();
    }
    e
}

#[test]
fn storm_every_accepted_request_replies_exactly_once_across_seeds() {
    for seed in SEEDS {
        let mut rng = SplitMix64::new(seed);
        let producers = rng.usize_in(3, 5);
        let per_producer = rng.usize_in(10, 18);
        let max_queue = rng.usize_in(2, 5);
        let e = std::sync::Arc::new(storm_engine(max_queue, seed));
        let input_lens: Vec<usize> =
            ZOO.iter().map(|n| e.model(n).unwrap().input_len()).collect();

        let mut handles = Vec::new();
        for p in 0..producers {
            let e = e.clone();
            let input_lens = input_lens.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = SplitMix64::stream(seed, p as u64);
                let mut accepted = Vec::new();
                let mut shed = 0u64;
                for _ in 0..per_producer {
                    let m = rng.usize_in(0, ZOO.len() - 1);
                    // ~1 in 4 submissions carries a bad shape: the
                    // engine must answer it with a typed error
                    let bad = rng.usize_in(0, 3) == 0;
                    let len = input_lens[m] + usize::from(bad);
                    match e.try_submit(ZOO[m], vec![0.25f32; len]) {
                        Ok(rx) => accepted.push((bad, rx)),
                        Err(SubmitError::Rejected(rej)) => {
                            // typed refusal with an actionable hint
                            assert!(
                                matches!(
                                    rej.reason,
                                    ShedReason::QueueFull | ShedReason::OverBudget
                                ),
                                "untyped shed"
                            );
                            assert!(rej.retry_after_us >= 1, "shed without a retry hint");
                            assert!(rej.depth > 0);
                            shed += 1;
                        }
                        Err(SubmitError::UnknownModel(m)) => {
                            panic!("roster registered {m} up front")
                        }
                    }
                    if rng.usize_in(0, 7) == 0 {
                        // occasional think time lets deadline seals race
                        // admission seals
                        std::thread::sleep(Duration::from_micros(
                            rng.usize_in(50, 400) as u64
                        ));
                    }
                }
                // collect with a bound: a reply that never comes is a
                // lost request, and must fail the test, not hang it
                let mut ids = Vec::new();
                let mut errors = 0u64;
                for (bad, rx) in accepted {
                    match rx.recv_timeout(REPLY_BOUND).expect("accepted request lost its reply")
                    {
                        Ok(resp) => {
                            assert!(!bad, "a bad-shape request must not succeed");
                            ids.push(resp.id);
                        }
                        Err(_) => {
                            assert!(bad, "a well-formed request must not error");
                            errors += 1;
                        }
                    }
                }
                (per_producer as u64, shed, ids, errors)
            }));
        }

        let mut total_submitted = 0u64;
        let mut total_shed = 0u64;
        let mut total_errors = 0u64;
        let mut all_ids: Vec<u64> = Vec::new();
        for h in handles {
            let (submitted, shed, ids, errors) = h.join().unwrap();
            total_submitted += submitted;
            total_shed += shed;
            total_errors += errors;
            all_ids.extend(ids);
        }
        // exactly once: every accepted id answered, none twice
        let completed = all_ids.len() as u64;
        all_ids.sort_unstable();
        all_ids.dedup();
        assert_eq!(all_ids.len() as u64, completed, "seed {seed}: duplicate replies");
        // conservation: submitted = completed + errored + shed
        assert_eq!(
            completed + total_errors + total_shed,
            total_submitted,
            "seed {seed}: requests leaked"
        );
        // the engine's own ledger agrees with the clients'
        let m = e.metrics();
        assert_eq!(m.requests.load(Relaxed), total_submitted, "seed {seed}");
        assert_eq!(m.completed.load(Relaxed), completed, "seed {seed}");
        assert_eq!(m.errors.load(Relaxed), total_errors, "seed {seed}");
        let (sq, sb) = m.shed_counts();
        assert_eq!(sq + sb, total_shed, "seed {seed}: typed shed split must cover sheds");
        assert_eq!(sb, 0, "seed {seed}: a 5s SLO must never shed over-budget");
        // dispatch accounting covers exactly the worker-served requests
        let (batched, singleton) = m.dispatch_counts();
        assert_eq!(batched + singleton, completed + total_errors, "seed {seed}");
        // and the engine still serves cleanly after the storm
        let ok = e
            .infer("mlp", vec![0.5; e.model("mlp").unwrap().input_len()])
            .expect("engine must recover after the storm");
        assert!(!ok.logits.is_empty());
        // all clients joined: dropping the engine drains the workers
        drop(e);
    }
}

/// Pure-scheduler fixture: synthetic cost curve `svc(n) = n·step`.
fn sched(cfg: SchedulerConfig, step: u64) -> Scheduler<u64> {
    Scheduler::new(cfg, Box::new(move |_, n| n as u64 * step))
}

#[test]
fn edf_pop_order_matches_min_deadline_oracle_across_seeds() {
    for seed in SEEDS {
        let mut rng = SplitMix64::new(seed);
        let models = rng.usize_in(2, 5);
        let mut s = sched(
            SchedulerConfig {
                max_batch: rng.usize_in(1, 4),
                max_wait: Duration::from_millis(2),
                max_queue: 4096,
                slo: Duration::from_millis(50),
                cost_flush: false,
                shed_over_budget: false,
            },
            100,
        );
        for m in 0..models {
            s.register(&format!("m{m}"));
        }
        // random interleaved arrivals over virtual time, with the
        // deadline rule sealing behind them
        let n = rng.usize_in(20, 60);
        let mut t = 0u64;
        for i in 0..n {
            t += rng.usize_in(0, 500_000) as u64;
            s.on_tick(t);
            let m = rng.usize_in(0, models - 1);
            s.submit(m, i as u64, t).expect("deep queue never sheds");
        }
        s.seal_all_drained();
        // a single consumer must pop in exactly min-deadline order
        let mut popped = 0usize;
        let mut last = 0u64;
        while let Some(oracle) = s.min_sealed_deadline() {
            let d = s.pop(t, None).expect("sealed work must pop");
            assert_eq!(
                d.front_deadline_ns, oracle,
                "seed {seed}: EDF must serve the earliest deadline first"
            );
            assert!(!d.stolen && !d.inversion, "seed {seed}: global pop is never a steal");
            assert!(d.front_deadline_ns >= last, "seed {seed}: deadlines ran backwards");
            last = d.front_deadline_ns;
            popped += d.entries.len();
        }
        assert!(s.is_empty(), "seed {seed}");
        assert_eq!(popped, n, "seed {seed}: every admitted request dispatched");
    }
}

#[test]
fn shard_affinity_flags_steals_and_inversions_truthfully() {
    // two models × two workers: model id % 2 is the home shard
    let mut s = sched(
        SchedulerConfig {
            max_batch: 1, // every submit seals instantly
            max_wait: Duration::from_secs(1),
            max_queue: 16,
            slo: Duration::from_millis(10),
            cost_flush: false,
            shed_over_budget: false,
        },
        100,
    );
    let a = s.register("a"); // home: worker 0
    let b = s.register("b"); // home: worker 1
    // b's batch is strictly older → earlier global EDF deadline
    s.submit(b, 1, 0).unwrap();
    s.submit(a, 2, 1_000).unwrap();
    // worker 0 serves its home shard past b's earlier deadline: an
    // EDF inversion, not a steal
    let d = s.pop(2_000, Some((0, 2))).unwrap();
    assert_eq!(d.model, a);
    assert!(d.inversion && !d.stolen);
    // worker 0's shard is now empty: taking b's batch is a steal of
    // the global EDF front, not an inversion
    let d = s.pop(2_000, Some((0, 2))).unwrap();
    assert_eq!(d.model, b);
    assert!(d.stolen && !d.inversion);
    assert!(s.is_empty());
    // when the home shard also holds the global front, neither flag
    s.submit(b, 3, 10_000).unwrap();
    let d = s.pop(11_000, Some((1, 2))).unwrap();
    assert_eq!(d.model, b);
    assert!(!d.stolen && !d.inversion);
}

#[test]
fn budget_seal_fires_exactly_at_the_marginal_latency_point() {
    // svc(n) = n ms against a 10ms SLO: admitting n leaves the batch
    // open iff svc(n+1) ≤ 10ms, so the seal lands exactly on the 10th
    // admission (svc(11) = 11ms breaks the budget)
    let mut s = sched(
        SchedulerConfig {
            max_batch: 64,
            max_wait: Duration::from_secs(1),
            max_queue: 1024,
            slo: Duration::from_millis(10),
            cost_flush: true,
            shed_over_budget: false,
        },
        MS,
    );
    let m = s.register("m");
    for i in 0..9 {
        let a = s.submit(m, i, 0).unwrap();
        assert!(!a.sealed, "admission {i}: svc({}) still fits the SLO", i + 2);
    }
    let a = s.submit(m, 9, 0).unwrap();
    assert!(a.sealed, "the 10th admission must seal: svc(11) > SLO");
    let d = s.pop(0, None).unwrap();
    assert_eq!(d.reason, FlushReason::Budget);
    assert_eq!(d.entries.len(), 10);

    // clock-driven flush point: one request at t=0 leaves 10−2 = 8ms
    // of margin for a second column, so the batch seals Budget just
    // past t = 8ms — and strictly before its 1s deadline
    s.submit(m, 10, 0).unwrap();
    s.on_tick(8 * MS);
    assert!(!s.has_sealed(), "the margin has not expired at 8ms");
    let wake = s.next_wakeup(0).unwrap();
    assert_eq!(wake, 8 * MS + 1, "wakeup is the exact marginal-latency expiry");
    s.on_tick(wake);
    let d = s.pop(wake, None).unwrap();
    assert_eq!(d.reason, FlushReason::Budget);
    assert_eq!(d.entries.len(), 1);

    // deadline precedence: with max_wait below the budget point, the
    // same shape seals Deadline instead
    let mut s = sched(
        SchedulerConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(5),
            max_queue: 1024,
            slo: Duration::from_millis(10),
            cost_flush: true,
            shed_over_budget: false,
        },
        MS,
    );
    let m = s.register("m");
    s.submit(m, 0, 0).unwrap();
    s.on_tick(6 * MS);
    let d = s.pop(6 * MS, None).unwrap();
    assert_eq!(d.reason, FlushReason::Deadline);
}

#[test]
fn compiled_cost_curve_is_positive_and_monotone() {
    // the curve the admission controller consults (both live and in
    // the virtual DES) must be a sane service-time model
    let model = tiny("deepspeech", 7);
    let cost = |n: usize| model.dispatch_cost_ns(n).expect("compiled models carry a cost");
    assert!(cost(1) >= 1, "a dispatch costs time");
    for (a, b) in [(1, 2), (2, 4), (4, 8), (8, 16)] {
        assert!(
            cost(b) >= cost(a),
            "serving {b} columns must not be modeled cheaper than {a} ({} < {})",
            cost(b),
            cost(a)
        );
    }
}

#[test]
fn worker_stall_fault_delays_but_never_loses_replies() {
    for seed in SEEDS {
        let stall = Duration::from_millis(150);
        // clock starts before the workers spawn, so every reply must
        // land at least one full stall after t0
        let t0 = Instant::now();
        let e = Engine::new_with_faults(
            EngineConfig {
                workers: 2,
                sched: SchedulerConfig {
                    max_batch: 4,
                    max_wait: Duration::from_millis(1),
                    max_queue: 64,
                    slo: Duration::from_secs(5),
                    ..SchedulerConfig::default()
                },
                router: RouterConfig::default(),
                store: StoreConfig::default(),
            },
            FaultPlan { worker_stall: stall, ..FaultPlan::default() },
        );
        e.register_model("ds", tiny("deepspeech", seed)).unwrap();
        let len = e.model("ds").unwrap().input_len();
        let rxs: Vec<_> = (0..8)
            .map(|_| e.try_submit("ds", vec![0.1; len]).expect("queue sized for the load"))
            .collect();
        for rx in rxs {
            rx.recv_timeout(REPLY_BOUND)
                .expect("stalled workers must still answer")
                .expect("well-formed requests succeed");
        }
        // replies cannot predate the stalled pool waking up
        assert!(
            t0.elapsed() >= stall,
            "seed {seed}: replies arrived before the stall ended"
        );
        assert_eq!(e.metrics().completed.load(Relaxed), 8, "seed {seed}");
        e.shutdown();
    }
}

#[test]
fn slow_model_fault_degrades_only_its_own_shard() {
    // model ids shard across the two workers, so the slow model's
    // +200ms dispatches occupy only its home worker; the fast model's
    // replies must not wait behind them
    let slow_extra = Duration::from_millis(200);
    let e = Engine::new_with_faults(
        EngineConfig {
            workers: 2,
            sched: SchedulerConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                max_queue: 64,
                slo: Duration::from_secs(5),
                ..SchedulerConfig::default()
            },
            router: RouterConfig::default(),
            store: StoreConfig::default(),
        },
        FaultPlan { slow_models: vec![("slow".to_string(), slow_extra)], ..FaultPlan::default() },
    );
    e.register_model("slow", tiny("deepspeech", 3)).unwrap();
    e.register_model("fast", tiny("mlp", 4)).unwrap();
    let slow_len = e.model("slow").unwrap().input_len();
    let fast_len = e.model("fast").unwrap().input_len();
    let t0 = Instant::now();
    let slow_rx = e.try_submit("slow", vec![0.1; slow_len]).unwrap();
    let fast_rx = e.try_submit("fast", vec![0.1; fast_len]).unwrap();
    fast_rx
        .recv_timeout(REPLY_BOUND)
        .expect("fast model must not starve")
        .expect("fast reply ok");
    let fast_elapsed = t0.elapsed();
    slow_rx
        .recv_timeout(REPLY_BOUND)
        .expect("slow model still answers")
        .expect("slow reply ok");
    let slow_elapsed = t0.elapsed();
    // the injected latency lands on the slow shard only: the fast
    // reply beats the slow model's injected floor, the slow one pays it
    assert!(
        fast_elapsed < slow_extra,
        "fast reply waited on the slow shard ({fast_elapsed:?})"
    );
    assert!(
        slow_elapsed >= slow_extra,
        "slow dispatch skipped its injected latency ({slow_elapsed:?})"
    );
    assert_eq!(e.metrics().completed.load(Relaxed), 2);
    e.shutdown();
}

#[test]
fn poisoned_reply_channels_neither_hang_workers_nor_leak_requests() {
    for seed in SEEDS {
        let e = Engine::new(EngineConfig {
            workers: 2,
            sched: SchedulerConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                max_queue: 64,
                slo: Duration::from_secs(5),
                ..SchedulerConfig::default()
            },
            router: RouterConfig::default(),
            store: StoreConfig::default(),
        });
        e.register_model("ds", tiny("deepspeech", seed)).unwrap();
        let len = e.model("ds").unwrap().input_len();
        let total = 12usize;
        let rxs: Vec<_> = (0..total)
            .map(|_| e.try_submit("ds", vec![0.2; len]).expect("queue sized for the load"))
            .collect();
        // poison every other reply channel: the client walks away and
        // drops the receiver while the request is (possibly) in flight
        let mut kept = Vec::new();
        for (i, rx) in rxs.into_iter().enumerate() {
            if i % 2 == 0 {
                drop(rx); // poisoned
            } else {
                kept.push(rx);
            }
        }
        // surviving channels each get exactly one reply, boundedly
        for rx in kept {
            rx.recv_timeout(REPLY_BOUND)
                .expect("a poisoned sibling must not cost this reply")
                .expect("well-formed requests succeed");
        }
        // workers served the full dozen — a dropped receiver is the
        // client's loss, never the worker's problem
        let deadline = Instant::now() + REPLY_BOUND;
        while e.metrics().completed.load(Relaxed) < total as u64 {
            assert!(Instant::now() < deadline, "seed {seed}: dispatches stuck");
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(e.metrics().errors.load(Relaxed), 0, "seed {seed}");
        // and the engine remains fully serviceable
        let ok = e.infer("ds", vec![0.3; len]).expect("engine survives poisoning");
        assert!(!ok.logits.is_empty());
        e.shutdown();
    }
}

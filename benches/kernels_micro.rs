//! Bench: per-kernel micro benchmarks — the §Perf profiling tool.
//! Reports ns/call, elements/ns and weight-GB/s for every FullPack
//! variant and baseline at three representative sizes (L1-resident,
//! LLC-resident, DRAM-streaming on the host).
//!
//! Run: `cargo bench --bench kernels_micro` (QUICK=1 for less sampling)

use fullpack::costmodel::Method;
use fullpack::figures::ondevice::measure_method;
use fullpack::models::FcShape;
use fullpack::util::bench::Table;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let ms = if quick { 8 } else { 60 };
    let shapes = [(256usize, 256usize), (2048, 2048), (8192, 4096)];
    // registry names — the shared modeled/measured namespace
    let methods = [
        "ruy-w8a8", "xnn-w8a8", "tflite-w8a8", "gemmlowp-w8a8",
        "fullpack-w4a8", "fullpack-w8a4", "fullpack-w4a4", "fullpack-w2a8", "fullpack-w8a2",
        "fullpack-w2a2", "fullpack-w1a8", "fullpack-w8a1", "fullpack-w1a1",
        "ruy-f32", "eigen-f32", "tflite-f32", "ulppack-w2a2", "ulppack-w1a1",
    ];
    for (z, k) in shapes {
        println!("\n== {z}x{k} ==");
        let mut t = Table::new(vec!["kernel", "us/call", "elems/ns", "wt GB/s", "vs ruy"]);
        let fc = FcShape { name: "micro", z, k };
        let base = measure_method(&fc, "ruy-w8a8", 3, ms).median_ns;
        for m in methods {
            let r = measure_method(&fc, m, 3, ms);
            // weight bytes from the cost model — same namespace, no
            // per-name parsing
            let wbytes = Method::from_registry(m)
                .map(|mm| (z * mm.weight_bytes_per_row(k)) as f64)
                .unwrap_or((z * k) as f64);
            t.row(vec![
                m.to_string(),
                format!("{:.1}", r.micros()),
                format!("{:.2}", (z * k) as f64 / r.median_ns),
                format!("{:.2}", wbytes / r.median_ns),
                format!("{:.2}x", base / r.median_ns),
            ]);
        }
        t.print();
    }
}

//! Bench: per-kernel micro benchmarks — the §Perf profiling tool.
//! Reports ns/call, elements/ns and weight-GB/s for every FullPack
//! variant and baseline at three representative sizes (L1-resident,
//! LLC-resident, DRAM-streaming on the host).
//!
//! Run: `cargo bench --bench kernels_micro` (QUICK=1 for less sampling)

use fullpack::figures::ondevice::measure_method;
use fullpack::models::FcShape;
use fullpack::util::bench::Table;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let ms = if quick { 8 } else { 60 };
    let shapes = [(256usize, 256usize), (2048, 2048), (8192, 4096)];
    let methods = [
        "ruy-w8a8", "xnn-w8a8", "tflite-w8a8", "gemmlowp-w8a8",
        "w4a8", "w8a4", "w4a4", "w2a8", "w8a2", "w2a2", "w1a8", "w8a1", "w1a1",
        "ruy-f32", "eigen-f32", "tflite-f32", "ulppack-w2a2", "ulppack-w1a1",
    ];
    for (z, k) in shapes {
        println!("\n== {z}x{k} ==");
        let mut t = Table::new(vec!["kernel", "us/call", "elems/ns", "wt GB/s", "vs ruy"]);
        let fc = FcShape { name: "micro", z, k };
        let base = measure_method(&fc, "ruy-w8a8", 3, ms).median_ns;
        for m in methods {
            let r = measure_method(&fc, m, 3, ms);
            let wbytes: f64 = match m {
                m if m.ends_with("f32") => (4 * z * k) as f64,
                m if m.starts_with("ulppack") => (z * k) as f64,
                m if m.starts_with('w') => {
                    let wb: usize = m[1..2].parse().unwrap();
                    (z * k * wb) as f64 / 8.0
                }
                _ => (z * k) as f64,
            };
            t.row(vec![
                m.to_string(),
                format!("{:.1}", r.micros()),
                format!("{:.2}", (z * k) as f64 / r.median_ns),
                format!("{:.2}", wbytes / r.median_ns),
                format!("{:.2}x", base / r.median_ns),
            ]);
        }
        t.print();
    }
}

//! Bench: the GEMM tier's batch sweep (DESIGN.md §9) — one batched
//! `fullpack-*-gemm` call vs `batch` repeated FullPack GEMVs vs the
//! paper's Ruy-like W8A8 GEMM protocol, across flush sizes.  The
//! crossover batch (first size where the batched call wins) feeds the
//! EXPERIMENTS.md GEMM-vs-repeated-GEMV table; the raw records go to
//! `BENCH_gemm.json` (schema `bench-gemm/v2`: wall-clock timings plus
//! the modeled per-level cache stats of each call from
//! `costmodel::simulate_gemm_traced` — one weight pass for the GEMM
//! tier, `batch` re-streams for the rivals).  Running this bench on a
//! real host replaces the committed cost-model placeholder with
//! measured timings (the cache columns stay model-side: hosts have no
//! portable cache counters).
//!
//! Run: `cargo bench --bench gemm_batch_sweep` (QUICK=1 for less
//! sampling; BENCH_OUT=path to redirect the JSON).

use fullpack::costmodel::{simulate_gemm_traced, CoreModel, Method};
use fullpack::figures::STEADY_CALLS;
use fullpack::kernels::testutil::rngvals;
use fullpack::kernels::{LayerShape, PlanBuilder, SelectPolicy};
use fullpack::pack::{BitWidth, Variant};
use fullpack::sim::CachePreset;
use fullpack::util::bench::{bench, write_gemm_bench_json, GemmBenchRecord, Table};

const VARIANTS: [&str; 3] = ["w4a8", "w2a8", "w1a8"];
const BATCHES: [usize; 6] = [1, 2, 4, 8, 16, 32];

/// The modeled cache half of one record: steady-state per-level stats
/// of the batched call under the paper's default hierarchy.
fn modeled_stats(method: Method, z: usize, k: usize, batch: usize) -> (u64, u64, u64, u64, u64) {
    let core = CoreModel::ex5_big();
    let (sim, replay) =
        simulate_gemm_traced(method, z, k, batch, CachePreset::Gem5Ex5Big, &core, STEADY_CALLS);
    (sim.l1.accesses, sim.l1.misses, sim.llc.accesses, sim.llc.misses, replay.weights.llc_misses)
}

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let ms = if quick { 8 } else { 50 };
    let (z, k) = (1024usize, 2048usize);
    let mut records: Vec<GemmBenchRecord> = Vec::new();
    // the Ruy-like rival's traffic is variant-independent: model its
    // cache stats once per batch, not once per variant
    let ruy_stats: Vec<(u64, u64, u64, u64, u64)> =
        BATCHES.iter().map(|&b| modeled_stats(Method::RuyW8A8, z, k, b)).collect();
    for vname in VARIANTS {
        let v = Variant::parse(vname).unwrap();
        println!("\n== {vname} {z}x{k} ==");
        let mut t = Table::new(vec![
            "batch",
            "gemm us",
            "repeated us",
            "ruy-like us",
            "gemm/col gain",
        ]);
        // one weight matrix, three execution protocols
        let w = rngvals(v.w, z * k, 3);
        let gemm_plan = PlanBuilder::new(LayerShape { z, k, batch: 2 }, v)
            .prefer_gemm(true)
            .build()
            .unwrap();
        assert_eq!(gemm_plan.kernel_name(), format!("fullpack-{vname}-gemm"));
        let gemv_plan = PlanBuilder::new(LayerShape { z, k, batch: 1 }, v).build().unwrap();
        let ruy_plan = PlanBuilder::new(LayerShape { z, k, batch: 2 }, v)
            .policy(SelectPolicy::Explicit("ruy-like-w8a8-gemm".into()))
            .build()
            .unwrap();
        let wg = gemm_plan.prepare_weights(&w).unwrap();
        let wv = gemv_plan.prepare_weights(&w).unwrap();
        let wr = ruy_plan.prepare_weights(&w).unwrap();
        let mut crossover: Option<usize> = None;
        for (bi, batch) in BATCHES.into_iter().enumerate() {
            let flat: Vec<i8> = (0..batch)
                .flat_map(|c| rngvals(BitWidth::B8, k, 10 + c as u64))
                .collect();
            let mut out = vec![0i32; z * batch];
            let mg = bench(
                || gemm_plan.execute_batch(&wg, &flat, batch, &mut out).unwrap(),
                2,
                ms,
                100_000,
            );
            let mr = bench(
                || {
                    for c in 0..batch {
                        gemv_plan
                            .execute(&wv, &flat[c * k..(c + 1) * k], &mut out[c * z..(c + 1) * z])
                            .unwrap();
                    }
                },
                2,
                ms,
                100_000,
            );
            let mruy = bench(
                || ruy_plan.execute_batch(&wr, &flat, batch, &mut out).unwrap(),
                2,
                ms,
                100_000,
            );
            for (name, m, method) in [
                (format!("fullpack-{vname}-gemm"), &mg, Some(Method::FullPackGemm(v))),
                (format!("repeated:fullpack-{vname}"), &mr, Some(Method::FullPack(v))),
                ("ruy-like-w8a8-gemm".to_string(), &mruy, None),
            ] {
                let (l1_accesses, l1_misses, llc_accesses, llc_misses, weight_llc_misses) =
                    match method {
                        Some(method) => modeled_stats(method, z, k, batch),
                        None => ruy_stats[bi],
                    };
                records.push(GemmBenchRecord {
                    kernel: name,
                    variant: vname.to_string(),
                    z,
                    k,
                    batch,
                    median_ns: m.median_ns,
                    iters: m.iters,
                    l1_accesses,
                    l1_misses,
                    llc_accesses,
                    llc_misses,
                    weight_llc_misses,
                });
            }
            if crossover.is_none() && batch >= 2 && mg.median_ns < mr.median_ns {
                crossover = Some(batch);
            }
            t.row(vec![
                batch.to_string(),
                format!("{:.1}", mg.micros()),
                format!("{:.1}", mr.micros()),
                format!("{:.1}", mruy.micros()),
                format!("{:.2}x", mr.median_ns / mg.median_ns),
            ]);
        }
        t.print();
        match crossover {
            Some(b) => println!("crossover: batched GEMM wins from batch {b}"),
            None => println!("crossover: repeated GEMV stayed ahead up to batch 32"),
        }
    }
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_gemm.json".to_string());
    let host = format!("{}-{}", std::env::consts::ARCH, std::env::consts::OS);
    let note = "measured by benches/gemm_batch_sweep.rs; ns_per_col = median_ns / batch; \
                repeated:* rows time `batch` back-to-back GEMV calls on the same weights; \
                cache columns are MODELED (costmodel::simulate_gemm_traced, gem5-ex5-big \
                preset, steady state) — one weight pass for fullpack-*-gemm, batch \
                re-streams for rivals; see EXPERIMENTS.md";
    match write_gemm_bench_json(&out, "measured", &host, note, &records) {
        Ok(()) => println!("\nwrote {} records to {out}", records.len()),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }
}

//! Bench: paper Figs. 1 and 10 — measured end-to-end DeepSpeech with
//! per-layer breakdown through the serving engine, every variant.
//!
//! Run: `cargo bench --bench e2e_deepspeech` (QUICK=1 uses the tiny
//! config).  The simulated (gem5-stand-in) version of the same figure
//! is `fullpack simulate fig10`.

use fullpack::models::{DeepSpeech, DeepSpeechConfig};
use fullpack::pack::Variant;
use fullpack::util::bench::Table;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let cfg = if quick { DeepSpeechConfig::TINY } else { DeepSpeechConfig::FULL };
    let runs = if quick { 2 } else { 4 };
    let frames: Vec<f32> =
        (0..cfg.time_steps * cfg.n_input).map(|i| (i as f32 * 0.01).sin()).collect();
    let variants = ["w8a8", "w4a8", "w4a4", "w2a2", "w1a1"];
    println!(
        "DeepSpeech measured per-layer breakdown (hidden={}, T={})\n",
        cfg.n_hidden, cfg.time_steps
    );
    let mut t = Table::new(vec!["variant", "fc1", "fc2", "fc3", "lstm", "fc5", "fc6", "total ms", "lstm %"]);
    let mut totals = Vec::new();
    for v in variants {
        let model = DeepSpeech::new(cfg, Variant::parse(v).unwrap(), 7);
        model.forward_timed(&frames); // warmup
        let mut best: Option<Vec<(String, u128)>> = None;
        let mut best_total = u128::MAX;
        for _ in 0..runs {
            let (_, times) = model.forward_timed(&frames);
            let total: u128 = times.iter().map(|(_, t)| t).sum();
            if total < best_total {
                best_total = total;
                best = Some(times);
            }
        }
        let times = best.unwrap();
        let lstm = times.iter().find(|(n, _)| *n == "lstm").unwrap().1;
        let mut row = vec![v.to_string()];
        row.extend(times.iter().map(|(_, ns)| format!("{:.2}", *ns as f64 / 1e6)));
        row.push(format!("{:.2}", best_total as f64 / 1e6));
        row.push(format!("{:.0}%", lstm as f64 / best_total as f64 * 100.0));
        t.row(row);
        totals.push((v, best_total));
    }
    t.print();
    let base = totals.iter().find(|(v, _)| *v == "w8a8").unwrap().1 as f64;
    println!("\nend-to-end speedup vs w8a8 (paper §4.6: 1.56-2.11x on gem5;");
    println!("host LLC is far larger than the paper's 2MB, see EXPERIMENTS.md):");
    for (v, t) in &totals {
        println!("  {v:>5}: {:.2}x", base / *t as f64);
    }
}

//! Bench: regenerate ALL simulated paper figures at the full IO-size
//! grid — Fig. 4, 5, 6, 7, 8, 10 (+1), 12, 13 — on the cache simulator
//! and cost model (the gem5 stand-in).
//!
//! Run: `cargo bench --bench sim_figures` (QUICK=1 for the small grid)

use fullpack::costmodel::Method;
use fullpack::figures::{e2e, sweeps, SIZES, SIZES_QUICK};
use fullpack::models::DeepSpeechConfig;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let sizes: &[usize] = if quick { &SIZES_QUICK } else { &SIZES };
    for (name, f) in [
        ("fig4", sweeps::fig4 as fn(&[usize]) -> sweeps::FigureReport),
        ("fig5", sweeps::fig5),
        ("fig6", sweeps::fig6),
        ("fig7", sweeps::fig7),
        ("fig8", sweeps::fig8),
        ("fig12", sweeps::fig12),
        ("fig13", sweeps::fig13),
        // not a paper figure: the GEMM tier's memory-aware batch sweep
        ("gemm-batch", sweeps::fig_gemm_batch),
        // not a paper figure: the LUT tier's table-vs-L1 crossover sweep
        ("lut-crossover", sweeps::fig_lut_crossover),
        // not a paper figure: the real-ISA tier vs staged/SWAR sweep
        ("isa-crossover", sweeps::fig_isa_crossover),
    ] {
        let t0 = std::time::Instant::now();
        let report = f(sizes);
        report.print();
        eprintln!("[{name} regenerated in {:.1}s]", t0.elapsed().as_secs_f64());
    }
    let t0 = std::time::Instant::now();
    let (table, totals) = e2e::fig10(DeepSpeechConfig::FULL);
    println!("=== fig10 (simulated DeepSpeech breakdown) ===\n");
    table.print();
    let base = totals.iter().find(|(n, _)| n == "Ruy-W8A8").unwrap().1;
    println!("\nend-to-end speedups vs Ruy-W8A8 (paper: 1.56-2.11x for FullPack):");
    for (n, t) in &totals {
        println!("  {n:>16}: {:.2}x", base / t);
    }
    let share = e2e::lstm_share(Method::RuyW8A8, Method::RuyW8A8, DeepSpeechConfig::FULL);
    println!("\nfig1: LSTM share of Ruy-W8A8 runtime = {:.0}% (paper: >70%)", share * 100.0);
    eprintln!("[fig10/fig1 regenerated in {:.1}s]", t0.elapsed().as_secs_f64());
}

//! Bench: the LUT tier's crossover sweep (DESIGN.md §13) — each
//! `lut-*` GEMV backend vs its FullPack sibling (and, for `w4a4`, the
//! ULPPACK comparator) over the two axes the table trades on: output
//! rows `z` (amortizing the per-call table build) and depth `k` (the
//! table is `wb · 1KB`, so depth decides whether it is L1-resident).
//! A final section times one `lut-w4a8-gemm` batched call against
//! `batch` repeated `lut-w4a8` GEMVs — measuring on real silicon the
//! weight-stream-vs-table-scratch trade the cost model pins in
//! `lut_gemm_wrapper_trades_weight_stream_for_table_pressure` (the
//! modeled verdict at this shape favors the repeated calls: COL_TILE
//! live tables alias in L1, one rebuilt table stays resident).
//!
//! Records append to the `BENCH_kernels.json` family (schema
//! `bench-kernels/v1`); running on a real host replaces the committed
//! cost-model placeholder in the EXPERIMENTS.md crossover table.
//!
//! Run: `cargo bench --bench lut_sweep` (QUICK=1 for less sampling;
//! BENCH_OUT=path to redirect the JSON).

use fullpack::kernels::testutil::rngvals;
use fullpack::kernels::{LayerShape, PlanBuilder, SelectPolicy};
use fullpack::pack::Variant;
use fullpack::util::bench::{bench, write_bench_json, BenchRecord, Table};

const VARIANTS: [&str; 4] = ["w4a8", "w2a8", "w1a8", "w4a4"];
/// Row counts: below / around / above the build-amortization crossover.
const ZS: [usize; 3] = [128, 512, 2048];
/// Depths: table fits L1 (128 → ≤64KB) vs thrashes it (2048 → ≤1MB).
const KS: [usize; 2] = [128, 2048];
const GEMM_BATCH: usize = 8;

fn gemv_plan(name: &str, z: usize, k: usize, v: Variant) -> fullpack::kernels::Plan {
    PlanBuilder::new(LayerShape { z, k, batch: 1 }, v)
        .policy(SelectPolicy::Explicit(name.into()))
        .build()
        .unwrap_or_else(|e| panic!("plan {name} {z}x{k}: {e}"))
}

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let ms = if quick { 8 } else { 50 };
    let mut records: Vec<BenchRecord> = Vec::new();
    for vname in VARIANTS {
        let v = Variant::parse(vname).unwrap();
        for k in KS {
            println!("\n== {vname} k={k} ==");
            let mut rivals = vec![format!("fullpack-{vname}")];
            if vname == "w4a4" {
                rivals.push("ulppack-w4a4".to_string());
            }
            let mut headers = vec!["z".to_string(), "lut us".to_string()];
            headers.extend(rivals.iter().map(|r| format!("{r} us")));
            headers.push("lut gain".to_string());
            let mut t = Table::new(headers);
            for z in ZS {
                let w = rngvals(v.w, z * k, 3);
                let a = rngvals(v.a, k, 7);
                let mut out = vec![0i32; z];
                let mut time = |name: &str| {
                    let p = gemv_plan(name, z, k, v);
                    let wts = p.prepare_weights(&w).unwrap();
                    let m = bench(|| p.execute(&wts, &a, &mut out).unwrap(), 2, ms, 100_000);
                    records.push(BenchRecord {
                        kernel: name.to_string(),
                        variant: vname.to_string(),
                        z,
                        k,
                        median_ns: m.median_ns,
                        iters: m.iters,
                    });
                    m
                };
                let ml = time(&format!("lut-{vname}"));
                let rival_ms: Vec<_> = rivals.iter().map(|r| time(r)).collect();
                let mut row = vec![z.to_string(), format!("{:.1}", ml.micros())];
                row.extend(rival_ms.iter().map(|m| format!("{:.1}", m.micros())));
                row.push(format!("{:.2}x", rival_ms[0].median_ns / ml.median_ns));
                t.row(row);
            }
            t.print();
        }
    }
    // the GEMM wrapper: one tiled batched call vs repeated GEMVs on the
    // same prepared weights (per-tile tables built once per COL_TILE
    // columns instead of once per column)
    let v = Variant::parse("w4a8").unwrap();
    let (z, k) = (1024usize, 128usize);
    println!("\n== lut-w4a8-gemm {z}x{k} batch={GEMM_BATCH} ==");
    let w = rngvals(v.w, z * k, 3);
    let flat: Vec<i8> =
        (0..GEMM_BATCH).flat_map(|c| rngvals(v.a, k, 10 + c as u64)).collect();
    let gp = PlanBuilder::new(LayerShape { z, k, batch: GEMM_BATCH }, v)
        .policy(SelectPolicy::Explicit("lut-w4a8-gemm".into()))
        .build()
        .unwrap();
    assert_eq!(gp.kernel_name(), "lut-w4a8-gemm");
    let vp = gemv_plan("lut-w4a8", z, k, v);
    let wg = gp.prepare_weights(&w).unwrap();
    let wv = vp.prepare_weights(&w).unwrap();
    let mut out = vec![0i32; z * GEMM_BATCH];
    let mg = bench(|| gp.execute_batch(&wg, &flat, GEMM_BATCH, &mut out).unwrap(), 2, ms, 100_000);
    let mr = bench(
        || {
            for c in 0..GEMM_BATCH {
                vp.execute(&wv, &flat[c * k..(c + 1) * k], &mut out[c * z..(c + 1) * z]).unwrap();
            }
        },
        2,
        ms,
        100_000,
    );
    for (name, m) in [("lut-w4a8-gemm", &mg), ("repeated:lut-w4a8", &mr)] {
        records.push(BenchRecord {
            kernel: name.to_string(),
            variant: "w4a8".to_string(),
            z,
            k,
            median_ns: m.median_ns,
            iters: m.iters,
        });
    }
    println!(
        "gemm {:.1}us vs repeated {:.1}us ({:.2}x)",
        mg.micros(),
        mr.micros(),
        mr.median_ns / mg.median_ns
    );
    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_kernels.json".to_string());
    let host = format!("{}-{}", std::env::consts::ARCH, std::env::consts::OS);
    let note = "measured by benches/lut_sweep.rs; lut-* rows rebuild the per-position \
                byte table every call (z amortizes it, k decides L1 residency); \
                repeated:lut-w4a8 times 8 back-to-back GEMVs against one \
                lut-w4a8-gemm call; see EXPERIMENTS.md LUT crossover table";
    match write_bench_json(&out_path, "measured", &host, note, &records) {
        Ok(()) => println!("\nwrote {} records to {out_path}", records.len()),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
}

//! Bench: paper Fig. 11 — FC layers of eleven CNNs, measured on the
//! host (the Raspberry Pi 4 substitution, DESIGN.md §2).
//!
//! Run: `cargo bench --bench cnn_fc` (QUICK=1 for shorter sampling)

use fullpack::figures::ondevice::fig11;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let (warmup, ms) = if quick { (2, 10) } else { (10, 100) }; // paper: 10 warmup, 100 iters
    println!("Fig. 11: CNN FC layers, speedup vs Ruy-W8A8 (measured)\n");
    let (table, geo) = fig11(warmup, ms);
    table.print();
    println!("\ngeomean speedups vs ruy-w8a8 (paper: W1A1 1.2x, W2A2 1.5x, W4A4 1.43x):");
    for (m, g) in geo {
        println!("  {m:>14}: {g:.2}x");
    }
}

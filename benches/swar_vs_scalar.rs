//! Bench: the SWAR fast-path tier vs the staged scalar kernels
//! (DESIGN.md §8) across LLC-relevant shapes, for every variant the
//! tier implements — plus the real-ISA tier (DESIGN.md §15) for every
//! vector ISA the host actually supports (absent entries are skipped
//! with a note, so the JSON only ever holds executed numbers).  Writes
//! the measured records to `BENCH_kernels.json` (schema
//! `bench-kernels/v1`) — the file EXPERIMENTS.md's "measured" column is
//! populated from.  Running this bench on a real host replaces the
//! committed cost-model placeholder with measured numbers.
//!
//! Run: `cargo bench --bench swar_vs_scalar` (QUICK=1 for less
//! sampling; BENCH_OUT=path to redirect the JSON), or
//! `scripts/bench_host.sh` for the full three-suite sweep.

use fullpack::figures::ondevice::measure_method;
use fullpack::kernels::isa::{detected, isa_kernel_name, ISA_VARIANTS};
use fullpack::models::FcShape;
use fullpack::util::bench::{write_bench_json, BenchRecord, Table};

/// (staged scalar baseline, SWAR tier) pairs, matched per variant.
const PAIRS: [(&str, &str, &str); 4] = [
    ("fullpack-w4a8", "fullpack-w4a8-swar", "w4a8"),
    ("fullpack-w2a8", "fullpack-w2a8-swar", "w2a8"),
    ("fullpack-w1a8", "fullpack-w1a8-swar", "w1a8"),
    ("ruy-w8a8", "fullpack-w8a8-swar", "w8a8"),
];

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let ms = if quick { 8 } else { 60 };
    let shapes: [(usize, usize); 4] = [(256, 256), (1024, 1024), (2048, 2048), (4096, 4096)];
    let mut records: Vec<BenchRecord> = Vec::new();
    for (z, k) in shapes {
        println!("\n== {z}x{k} ==");
        let mut t = Table::new(vec!["variant", "scalar us", "swar us", "swar speedup"]);
        for (scalar, swar, variant) in PAIRS {
            let fc = FcShape { name: "swar-sweep", z, k };
            let base = measure_method(&fc, scalar, 3, ms);
            let fast = measure_method(&fc, swar, 3, ms);
            for (name, m) in [(scalar, &base), (swar, &fast)] {
                records.push(BenchRecord {
                    kernel: name.to_string(),
                    variant: variant.to_string(),
                    z,
                    k,
                    median_ns: m.median_ns,
                    iters: m.iters,
                });
            }
            t.row(vec![
                variant.to_string(),
                format!("{:.1}", base.micros()),
                format!("{:.1}", fast.micros()),
                format!("{:.2}x", base.median_ns / fast.median_ns),
            ]);
        }
        t.print();

        // the real-ISA tier, for whatever this host can execute (the
        // registry only holds executable entries, so a missing name
        // here means the ISA is absent — note it and move on)
        let isa = detected();
        if isa.count() == 0 {
            println!("(no vector ISA detected: skipping the fullpack-*-avx2/-neon records)");
        } else {
            let mut ti = Table::new(vec!["kernel", "isa us", "vs scalar"]);
            for kind in isa.kinds() {
                for v in ISA_VARIANTS {
                    let name = isa_kernel_name(v, kind).expect("ISA_VARIANTS are implemented");
                    let scalar = if v.w.is_sub_byte() {
                        format!("fullpack-{}", v.name())
                    } else {
                        "ruy-w8a8".to_string()
                    };
                    let fc = FcShape { name: "isa-sweep", z, k };
                    let base = measure_method(&fc, &scalar, 3, ms);
                    let fast = measure_method(&fc, name, 3, ms);
                    records.push(BenchRecord {
                        kernel: name.to_string(),
                        variant: v.name().to_string(),
                        z,
                        k,
                        median_ns: fast.median_ns,
                        iters: fast.iters,
                    });
                    ti.row(vec![
                        name.to_string(),
                        format!("{:.1}", fast.micros()),
                        format!("{:.2}x", base.median_ns / fast.median_ns),
                    ]);
                }
            }
            ti.print();
        }
    }
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_kernels.json".to_string());
    let host = format!("{}-{}", std::env::consts::ARCH, std::env::consts::OS);
    let note = "measured by benches/swar_vs_scalar.rs; \
                ns_per_elem = median_ns / (z*k); see EXPERIMENTS.md";
    match write_bench_json(&out, "measured", &host, note, &records) {
        Ok(()) => println!("\nwrote {} records to {out}", records.len()),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }
}

//! Bench: ablations of the design choices DESIGN.md calls out.
//!
//! 1. **Extraction schedule** — FullPack's stride-16 two-shift layout vs
//!    the naive adjacent layout (Alg. 1) at equal memory density: shows
//!    the packing *co-design* is what pays, not density alone.
//! 2. **Batched GEMM extension** — FullPack's one-extraction-per-block
//!    GEMM vs repeated GEMV at the same bit-width.
//! 3. **Scheduler policy** — serving-engine throughput with admission
//!    batching enabled vs per-request dispatch (max_batch = 1).
//! 4. **Router policy** — FullPack disabled (everything on Ruy) vs the
//!    paper's §4.6 split.
//!
//! Kernels are selected by registry name through `Plan`s — no kernel
//! function is named here (DESIGN.md §3).
//!
//! Run: `cargo bench --bench ablations` (QUICK=1 shortens sampling)

use fullpack::coordinator::{Engine, EngineConfig, RouterConfig, SchedulerConfig};
use fullpack::kernels::testutil::rngvals;
use fullpack::kernels::{LayerShape, PlanBuilder, SelectPolicy};
use fullpack::models::{DeepSpeech, DeepSpeechConfig};
use fullpack::pack::{BitWidth, Variant};
use fullpack::util::bench::{bench, Table};

fn explicit_plan(z: usize, k: usize, variant: Variant, kernel: &str) -> fullpack::kernels::Plan {
    PlanBuilder::new(LayerShape { z, k, batch: 1 }, variant)
        .policy(SelectPolicy::Explicit(kernel.to_string()))
        .build()
        .expect("registry kernel")
}

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let ms = if quick { 8 } else { 50 };

    // --- 1: extraction schedule ---
    println!("== ablation 1: FullPack layout vs naive Alg. 1 layout (same density) ==\n");
    let mut t = Table::new(vec!["bits", "fullpack us", "naive us", "co-design gain"]);
    for bits in [BitWidth::B4, BitWidth::B2, BitWidth::B1] {
        let (z, k) = (1024usize, 2048usize);
        let variant = Variant::new(bits, BitWidth::B8);
        let w = rngvals(bits, z * k, 1);
        let a = rngvals(BitWidth::B8, k, 2);
        let full_plan =
            explicit_plan(z, k, variant, &format!("fullpack-w{}a8", bits.bits()));
        let naive_plan =
            explicit_plan(z, k, variant, &format!("naive-w{}a8", bits.bits()));
        let wf = full_plan.prepare_weights(&w).unwrap();
        let wn = naive_plan.prepare_weights(&w).unwrap();
        let mut out = vec![0i32; z];
        let mf = bench(|| full_plan.execute(&wf, &a, &mut out).unwrap(), 2, ms, 100_000);
        let mn = bench(|| naive_plan.execute(&wn, &a, &mut out).unwrap(), 2, ms, 100_000);
        t.row(vec![
            format!("{}", bits.bits()),
            format!("{:.1}", mf.micros()),
            format!("{:.1}", mn.micros()),
            format!("{:.2}x", mn.median_ns / mf.median_ns),
        ]);
    }
    t.print();

    // --- 2: batched FullPack GEMM (the paper's future-work gap) ---
    println!("\n== ablation 2: FullPack GEMM extension vs repeated GEMV ==\n");
    let mut t = Table::new(vec!["batch", "repeated-gemv us", "batched-gemm us", "gain"]);
    {
        let (z, k) = (1024usize, 2048usize);
        let variant = Variant::parse("w4a8").unwrap();
        let plan = explicit_plan(z, k, variant, "fullpack-w4a8");
        let w = rngvals(BitWidth::B4, z * k, 3);
        let wts = plan.prepare_weights(&w).unwrap();
        for batch in [2usize, 4, 16] {
            let cols: Vec<Vec<i8>> =
                (0..batch).map(|c| rngvals(BitWidth::B8, k, 10 + c as u64)).collect();
            let flat: Vec<i8> = cols.concat();
            let mut out = vec![0i32; z * batch];
            // Plan::execute_batch routes to the kernel's batched GEMM
            // override (one weight extraction feeds all columns)
            let mg = bench(|| plan.execute_batch(&wts, &flat, batch, &mut out).unwrap(), 2, ms, 100_000);
            let mr = bench(
                || {
                    for (c, col) in cols.iter().enumerate() {
                        plan.execute(&wts, col, &mut out[c * z..(c + 1) * z]).unwrap();
                    }
                },
                2,
                ms,
                100_000,
            );
            t.row(vec![
                batch.to_string(),
                format!("{:.1}", mr.micros()),
                format!("{:.1}", mg.micros()),
                format!("{:.2}x", mr.median_ns / mg.median_ns),
            ]);
        }
    }
    t.print();
    println!(
        "\n(negative result on this host: after the §Perf vectorization fix the\n\
         per-call extraction is so cheap that amortizing it across columns\n\
         does not pay — the column-tiled loop trades it for worse activation\n\
         locality.  On an in-order NEON core, where the 2E-1 shifts per block\n\
         are a larger fraction of the inner loop, the balance shifts; the\n\
         kernel is kept as the future-work extension with exact tests.)"
    );

    // --- 3 & 4: engine policies ---
    println!("\n== ablation 3: serving policies (tiny model, 64 requests) ==\n");
    let cfg = DeepSpeechConfig::TINY;
    let frames: Vec<f32> =
        (0..cfg.time_steps * cfg.n_input).map(|i| (i as f32 * 0.01).sin()).collect();
    let mut t = Table::new(vec!["policy", "mean us", "p95", "rps"]);
    for (name, sched, router) in [
        ("batched + fullpack", SchedulerConfig::default(), RouterConfig::default()),
        (
            "no batching",
            SchedulerConfig { max_batch: 1, ..Default::default() },
            RouterConfig::default(),
        ),
        (
            "fullpack disabled",
            SchedulerConfig::default(),
            RouterConfig { disable_fullpack: true, ..Default::default() },
        ),
    ] {
        let engine = Engine::new(EngineConfig { workers: 2, sched, router });
        engine.register_model(
            "ds",
            DeepSpeech::new(cfg, Variant::parse("w4a8").unwrap(), 7),
        );
        let rxs: Vec<_> =
            (0..64).map(|_| engine.try_submit("ds", frames.clone()).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let m = engine.metrics();
        t.row(vec![
            name.to_string(),
            format!("{:.0}", m.mean_latency_us()),
            format!("{}us", m.latency_quantile_us(0.95)),
            format!("{:.0}", m.throughput_rps()),
        ]);
        engine.shutdown();
    }
    t.print();
    println!("\n(router ablation changes path stats, not tiny-model wall time;\n see `fullpack serve` for the full-size effect)");
}

//! Bench: the serve sweep — sample a set of workload mixes from the
//! default mix space and replay each one, emitting the fig-serve
//! tables and the `bench-serve/v3` document (`BENCH_serve.json`).
//!
//! Default mode is the deterministic virtual clock (cost-model service
//! times — same seed ⇒ byte-identical document apart from host/wall
//! fields).  Set `LIVE=1` to drive the real engine instead (wall-clock
//! latencies, host-dependent).
//!
//! Run: `cargo bench --bench serve_sweep`
//!      (QUICK=1 for fewer mixes, SEED=n / COUNT=n to steer the sweep,
//!       OUT=path to write the JSON document)

use fullpack::figures::serve::{fig_serve_dispatch, fig_serve_latency};
use fullpack::workload::{build_report, run_live, run_virtual, MixReport, MixSpace};

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let live = std::env::var("LIVE").is_ok();
    let seed = env_u64("SEED", 7);
    let count = env_u64("COUNT", if quick { 3 } else { 8 }) as usize;
    let mode = if live { "live" } else { "virtual-costmodel" };

    let space = MixSpace::default_space();
    let t0 = std::time::Instant::now();
    let mut reports: Vec<MixReport> = Vec::with_capacity(count);
    for mix in space.sample_all(seed, count) {
        let t1 = std::time::Instant::now();
        let trace = if live {
            run_live(&mix, false).expect("live replay")
        } else {
            run_virtual(&mix).expect("virtual replay")
        };
        let report = build_report(&mix, &trace).expect("report reconciles");
        eprintln!(
            "[{}: {}/{} completed, p99 {} us, replayed in {:.2}s]",
            report.mix,
            report.completed,
            report.issued,
            report.p99_us,
            t1.elapsed().as_secs_f64()
        );
        reports.push(report);
    }

    println!("=== fig-serve: latency/throughput ({mode}, seed {seed}) ===\n");
    fig_serve_latency(&reports).print();
    println!("\n=== fig-serve: dispatch mix ===\n");
    fig_serve_dispatch(&reports).print();

    if let Ok(out) = std::env::var("OUT") {
        let host = std::env::var("HOSTNAME").unwrap_or_else(|_| "unknown-host".into());
        let note = format!("serve sweep: seed {seed}, {count} mixes from the default space");
        fullpack::workload::write_serve_json(&out, mode, &host, &note, &reports)
            .expect("writing BENCH_serve.json");
        eprintln!("[wrote {out}]");
    }
    eprintln!("[serve sweep: {count} mixes in {:.1}s]", t0.elapsed().as_secs_f64());
}

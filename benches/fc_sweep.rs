//! Bench: measured FullyConnected GEMV sweep — the wall-clock analog of
//! paper Figs. 4 and 5 (the simulated versions live in
//! `fullpack simulate fig4|fig5`).  Prints speedup-vs-Ruy tables over
//! the IO-size grid for the FullPack variants and the rival baselines.
//!
//! Run: `cargo bench --bench fc_sweep` (QUICK=1 for a reduced grid)

use fullpack::figures::ondevice::measure_method;
use fullpack::models::FcShape;
use fullpack::util::bench::Table;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let sizes: &[usize] =
        if quick { &[256, 1024, 4096] } else { &[128, 256, 512, 1024, 2048, 4096] };
    let ms = if quick { 10 } else { 40 };
    // registry names — the shared modeled/measured namespace
    let methods = [
        "fullpack-w4a8", "fullpack-w8a4", "fullpack-w4a4", "fullpack-w2a2", "fullpack-w1a1",
        "xnn-w8a8", "tflite-w8a8", "gemmlowp-w8a8",
        "ruy-f32", "eigen-f32", "ulppack-w2a2", "ulppack-w1a1",
    ];
    println!("measured GEMV sweep (speedup = T_ruy-w8a8 / T_method), host CPU\n");
    for m in methods {
        let mut t = Table::new(
            std::iter::once("z\\k".to_string())
                .chain(sizes.iter().map(|k| k.to_string()))
                .collect::<Vec<_>>(),
        );
        let mut geo = 0.0;
        for &z in sizes {
            let mut row = vec![z.to_string()];
            for &k in sizes {
                let fc = FcShape { name: "sweep", z, k };
                let base = measure_method(&fc, "ruy-w8a8", 2, ms).median_ns;
                let ours = measure_method(&fc, m, 2, ms).median_ns;
                let s = base / ours;
                geo += s.ln();
                row.push(format!("{s:.2}"));
            }
            t.row(row);
        }
        println!("-- {m} --");
        t.print();
        println!(
            "geomean: {:.2}x\n",
            (geo / (sizes.len() * sizes.len()) as f64).exp()
        );
    }
}
